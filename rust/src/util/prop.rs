//! Property-test harness (proptest substitute).
//!
//! `check` runs a property over `n` generated cases from seeded RNG streams;
//! on failure it retries the same case once (to rule out flaky environment)
//! and then panics with the exact seed so the case reproduces with
//! `check_seed`.  No shrinking — generators here are small enough that the
//! seed plus the Debug dump of the input is directly diagnosable.

use super::rng::Rng;

/// Run `prop` over `cases` inputs drawn via `gen`. Panics on first failure
/// with the reproducing seed.
pub fn check<T, G, P>(name: &str, cases: u64, base_seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n  input: {input:?}\n  {msg}\n\
                 reproduce with util::prop::check_seed({seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<T, G, P>(seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let input = gen(&mut rng);
    if let Err(msg) = prop(&input) {
        panic!("seed {seed:#x} still failing: {msg}\n  input: {input:?}");
    }
}

/// Generator helpers.
pub mod gens {
    use super::Rng;
    use crate::nn::{Layer, Matrix, Mlp};

    pub fn vec_f32(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..len).map(|_| rng.uniform(lo, hi) as f32).collect()
    }

    pub fn matrix(rng: &mut Rng, rows: usize, cols: usize, lo: f64, hi: f64) -> Vec<f32> {
        vec_f32(rng, rows * cols, lo, hi)
    }

    /// Random MLP over `topo`, weights in `±w_amp`, biases in `±b_amp` —
    /// shared by the gemm property tests, the dispatcher scratch tests and
    /// the synthetic hotpath bench.
    pub fn mlp(rng: &mut Rng, topo: &[usize], w_amp: f64, b_amp: f64) -> Mlp {
        let layers: Vec<Layer> = topo
            .windows(2)
            .map(|w| Layer {
                w: Matrix::new(w[0], w[1], matrix(rng, w[0], w[1], -w_amp, w_amp)),
                b: vec_f32(rng, w[1], -b_amp, b_amp),
            })
            .collect();
        Mlp::new(layers)
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 64, 1, |r| (r.f64(), r.f64()), |&(a, b)| {
            if (a + b - (b + a)).abs() < 1e-12 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 4, 2, |r| r.f64(), |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0, 1.0).is_err());
    }
}
