//! Black–Scholes European option pricing (Financial Analysis, 6 -> 1).
//! Inputs: spot, strike, rate, volatility, time-to-expiry, type (0=call).

use super::special::norm_cdf;
use super::BenchFn;
use crate::util::rng::Rng;

pub struct BlackScholes;

impl BenchFn for BlackScholes {
    fn name(&self) -> &'static str {
        "blackscholes"
    }

    fn n_in(&self) -> usize {
        6
    }

    fn n_out(&self) -> usize {
        1
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let (s, k, r, v, t, otype) = (
            x[0] as f64, x[1] as f64, x[2] as f64, x[3] as f64, x[4] as f64, x[5] as f64,
        );
        let sqrt_t = t.sqrt();
        let d1 = ((s / k).ln() + (r + 0.5 * v * v) * t) / (v * sqrt_t);
        let d2 = d1 - v * sqrt_t;
        let disc = k * (-r * t).exp();
        let call = s * norm_cdf(d1) - disc * norm_cdf(d2);
        out[0] = if otype < 0.5 { call } else { call - s + disc };
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        let s = rng.lognormal(50.0f64.ln(), 0.35).clamp(10.0, 150.0);
        let k = s * rng.uniform(0.6, 1.4);
        out[0] = s as f32;
        out[1] = k as f32;
        out[2] = rng.uniform(0.01, 0.08) as f32;
        out[3] = rng.uniform(0.05, 0.65) as f32;
        out[4] = rng.uniform(0.1, 2.0) as f32;
        out[5] = if rng.bool(0.5) { 1.0 } else { 0.0 };
    }

    fn cpu_cycles(&self) -> u64 {
        // ln + exp + sqrt + 2x norm_cdf (exp + poly) + ~15 mul/add; scalar
        // libm transcendentals ~20-50 cycles each on a modern OoO core.
        240
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_call_parity() {
        let b = BlackScholes;
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let mut x = [0.0f32; 6];
            b.gen_into(&mut rng, &mut x);
            let mut call = [0.0f64];
            let mut put = [0.0f64];
            x[5] = 0.0;
            b.eval(&x, &mut call);
            x[5] = 1.0;
            b.eval(&x, &mut put);
            let (s, k, r, t) = (x[0] as f64, x[1] as f64, x[2] as f64, x[4] as f64);
            let parity = s - k * (-r * t).exp();
            assert!((call[0] - put[0] - parity).abs() < 1e-9);
        }
    }

    #[test]
    fn call_bounded_by_spot() {
        let b = BlackScholes;
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let mut x = [0.0f32; 6];
            b.gen_into(&mut rng, &mut x);
            x[5] = 0.0;
            let mut y = [0.0f64];
            b.eval(&x, &mut y);
            assert!(y[0] >= -1e-9 && y[0] <= x[0] as f64 + 1e-9);
        }
    }
}
