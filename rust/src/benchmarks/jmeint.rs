//! Triangle-triangle intersection (3D gaming, 18 -> 2): two triangles'
//! vertices -> one-hot (intersects, disjoint).  Mirrors the Python SAT.

use super::special::tri_tri_overlap;
use super::BenchFn;
use crate::util::rng::Rng;

pub struct Jmeint;

impl BenchFn for Jmeint {
    fn name(&self) -> &'static str {
        "jmeint"
    }

    fn n_in(&self) -> usize {
        18
    }

    fn n_out(&self) -> usize {
        2
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let mut p = [[0.0f64; 3]; 3];
        let mut q = [[0.0f64; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                p[i][j] = x[i * 3 + j] as f64;
                q[i][j] = x[9 + i * 3 + j] as f64;
            }
        }
        let hit = tri_tri_overlap(&p, &q);
        out[0] = if hit { 1.0 } else { 0.0 };
        out[1] = if hit { 0.0 } else { 1.0 };
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        // First triangle uniform in the unit cube; second shrunk 0.8x and
        // offset so ~half the pairs intersect (mirrors the Python gen).
        for v in out.iter_mut().take(9) {
            *v = rng.uniform(0.0, 1.0) as f32;
        }
        let off = [rng.uniform(-0.4, 0.4), rng.uniform(-0.4, 0.4), rng.uniform(-0.4, 0.4)];
        for i in 0..3 {
            for j in 0..3 {
                out[9 + i * 3 + j] = (rng.uniform(0.0, 1.0) * 0.8 + off[j]) as f32;
            }
        }
    }

    fn cpu_cycles(&self) -> u64 {
        // 11 axes x (cross product + 6 dots + compares) ~ 70 ops each.
        800
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_is_one_hot() {
        let b = Jmeint;
        let mut rng = Rng::new(7);
        let mut hits = 0;
        for _ in 0..300 {
            let mut x = [0.0f32; 18];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64; 2];
            b.eval(&x, &mut y);
            assert_eq!(y[0] + y[1], 1.0);
            if y[0] == 1.0 {
                hits += 1;
            }
        }
        // Generator keeps both classes populated (random triangle pairs
        // intersect ~10% of the time under this placement).
        assert!(hits > 10 && hits < 290, "hit rate degenerate: {hits}/300");
    }

    #[test]
    fn symmetric_in_triangle_order() {
        let b = Jmeint;
        let mut rng = Rng::new(8);
        for _ in 0..100 {
            let mut x = [0.0f32; 18];
            b.gen_into(&mut rng, &mut x);
            let mut swapped = [0.0f32; 18];
            swapped[..9].copy_from_slice(&x[9..]);
            swapped[9..].copy_from_slice(&x[..9]);
            let mut a = [0.0f64; 2];
            let mut c = [0.0f64; 2];
            b.eval(&x, &mut a);
            b.eval(&swapped, &mut c);
            assert_eq!(a, c);
        }
    }
}
