//! 2-joint inverse kinematics (Robotics, 2 -> 2): end-effector (x, y) ->
//! joint angles (theta1, theta2) for link lengths l1 = l2 = 0.5.

use super::BenchFn;
use crate::util::rng::Rng;

pub const L1: f64 = 0.5;
pub const L2: f64 = 0.5;

pub struct InverseK2j;

impl BenchFn for InverseK2j {
    fn name(&self) -> &'static str {
        "inversek2j"
    }

    fn n_in(&self) -> usize {
        2
    }

    fn n_out(&self) -> usize {
        2
    }

    fn eval(&self, xin: &[f32], out: &mut [f64]) {
        let (x, y) = (xin[0] as f64, xin[1] as f64);
        let d2 = x * x + y * y;
        let c2 = ((d2 - L1 * L1 - L2 * L2) / (2.0 * L1 * L2)).clamp(-1.0, 1.0);
        let th2 = c2.acos();
        let th1 = y.atan2(x) - (L2 * th2.sin()).atan2(L1 + L2 * th2.cos());
        out[0] = th1;
        out[1] = th2;
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        // Sample reachable poses exactly like the Python generator: draw
        // joint angles, run forward kinematics.
        let th1 = rng.uniform(0.05, std::f64::consts::FRAC_PI_2 - 0.05);
        let th2 = rng.uniform(0.05, std::f64::consts::FRAC_PI_2 - 0.05);
        out[0] = (L1 * th1.cos() + L2 * (th1 + th2).cos()) as f32;
        out[1] = (L1 * th1.sin() + L2 * (th1 + th2).sin()) as f32;
    }

    fn cpu_cycles(&self) -> u64 {
        // acos + 2x atan2 + sin/cos + ~10 ops.
        180
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_of_forward_kinematics() {
        let b = InverseK2j;
        let mut rng = Rng::new(6);
        for _ in 0..300 {
            let mut p = [0.0f32; 2];
            b.gen_into(&mut rng, &mut p);
            let mut th = [0.0f64; 2];
            b.eval(&p, &mut th);
            let x = L1 * th[0].cos() + L2 * (th[0] + th[1]).cos();
            let y = L1 * th[0].sin() + L2 * (th[0] + th[1]).sin();
            assert!((x - p[0] as f64).abs() < 1e-6, "{x} vs {}", p[0]);
            assert!((y - p[1] as f64).abs() < 1e-6);
        }
    }
}
