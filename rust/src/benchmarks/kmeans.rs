//! K-means distance kernel (Machine Learning, 6 -> 1): Euclidean distance
//! between an (r,g,b) pixel and a cluster centroid.

use super::BenchFn;
use crate::util::rng::Rng;

pub struct Kmeans;

impl BenchFn for Kmeans {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn n_in(&self) -> usize {
        6
    }

    fn n_out(&self) -> usize {
        1
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let mut s = 0.0f64;
        for i in 0..3 {
            let d = x[i] as f64 - x[i + 3] as f64;
            s += d * d;
        }
        out[0] = s.sqrt();
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        // Pixel uniform; centroid near one of 8 synthetic cluster centers
        // (same family as the Python generator, fresh centers per stream).
        for v in out.iter_mut().take(3) {
            *v = rng.uniform(0.0, 1.0) as f32;
        }
        for i in 0..3 {
            let center = (rng.below(8) as f64 + 0.5) / 8.0;
            out[3 + i] = (center + rng.normal_ms(0.0, 0.05)).clamp(0.0, 1.0) as f32;
        }
    }

    fn cpu_cycles(&self) -> u64 {
        // 3 sub/mul/add + sqrt.
        40
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_hand_checked() {
        let b = Kmeans;
        let mut y = [0.0f64];
        b.eval(&[0.0, 0.0, 0.0, 1.0, 1.0, 1.0], &mut y);
        assert!((y[0] - 3.0f64.sqrt()).abs() < 1e-9);
        b.eval(&[0.5, 0.5, 0.5, 0.5, 0.5, 0.5], &mut y);
        assert_eq!(y[0], 0.0);
    }

    #[test]
    fn distance_nonnegative_and_bounded() {
        let b = Kmeans;
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let mut x = [0.0f32; 6];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64];
            b.eval(&x, &mut y);
            assert!(y[0] >= 0.0 && y[0] <= 3.0f64.sqrt() + 1e-9);
        }
    }
}
