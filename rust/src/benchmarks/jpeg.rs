//! JPEG encode/decode round-trip of one 8x8 block (Compression, 64 -> 64):
//! DCT -> quantise (luminance Q50) -> dequantise -> IDCT.

use super::special::jpeg_roundtrip_block;
use super::BenchFn;
use crate::util::rng::Rng;

pub struct Jpeg;

impl BenchFn for Jpeg {
    fn name(&self) -> &'static str {
        "jpeg"
    }

    fn n_in(&self) -> usize {
        64
    }

    fn n_out(&self) -> usize {
        64
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let mut block = [0.0f32; 64];
        block.copy_from_slice(x);
        out.copy_from_slice(&jpeg_roundtrip_block(&block));
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        // Synthetic blocks: level + linear gradient + 2-D sinusoid + noise,
        // the same family as the Python generator.
        let gx = rng.uniform(-1.0, 1.0);
        let gy = rng.uniform(-1.0, 1.0);
        let phx = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        let phy = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        let fx = rng.uniform(0.2, 1.4);
        let fy = rng.uniform(0.2, 1.4);
        let amp = rng.uniform(0.0, 0.4);
        let level = rng.uniform(0.2, 0.8);
        for r in 0..8 {
            for c in 0..8 {
                let v = level
                    + gx * (c as f64 - 3.5) / 14.0
                    + gy * (r as f64 - 3.5) / 14.0
                    + amp * (fx * c as f64 + phx).sin() * (fy * r as f64 + phy).sin()
                    + rng.normal_ms(0.0, 0.02);
                out[r * 8 + c] = v.clamp(0.0, 1.0) as f32;
            }
        }
    }

    fn cpu_cycles(&self) -> u64 {
        // 4x 8x8x8 matmuls (2048 MACs) + 64 round/div + clamps.
        2600
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_in_unit_range() {
        let b = Jpeg;
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let mut x = [0.0f32; 64];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64; 64];
            b.eval(&x, &mut y);
            assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn reconstruction_close_to_input() {
        let b = Jpeg;
        let mut rng = Rng::new(10);
        let mut worst = 0.0f64;
        for _ in 0..50 {
            let mut x = [0.0f32; 64];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64; 64];
            b.eval(&x, &mut y);
            let rmse = (x
                .iter()
                .zip(&y)
                .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                .sum::<f64>()
                / 64.0)
                .sqrt();
            worst = worst.max(rmse);
        }
        assert!(worst < 0.2, "jpeg roundtrip rmse too big: {worst}");
    }
}
