//! Sobel gradient magnitude of a 3x3 luminance window (Image Processing,
//! 9 -> 1), normalised by 4*sqrt(2) and clamped to [0, 1].

use super::BenchFn;
use crate::util::rng::Rng;

const GX: [f64; 9] = [-1.0, 0.0, 1.0, -2.0, 0.0, 2.0, -1.0, 0.0, 1.0];
const GY: [f64; 9] = [-1.0, -2.0, -1.0, 0.0, 0.0, 0.0, 1.0, 2.0, 1.0];

pub struct Sobel;

impl BenchFn for Sobel {
    fn name(&self) -> &'static str {
        "sobel"
    }

    fn n_in(&self) -> usize {
        9
    }

    fn n_out(&self) -> usize {
        1
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let mut gx = 0.0f64;
        let mut gy = 0.0f64;
        for i in 0..9 {
            gx += x[i] as f64 * GX[i];
            gy += x[i] as f64 * GY[i];
        }
        out[0] = ((gx * gx + gy * gy).sqrt() / (4.0 * std::f64::consts::SQRT_2)).clamp(0.0, 1.0);
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        // Window = level + gradient + vertical step edge + noise.
        let gx = rng.uniform(-0.5, 0.5);
        let gy = rng.uniform(-0.5, 0.5);
        let level = rng.uniform(0.1, 0.9);
        let edge_pos = rng.uniform(-0.5, 2.5);
        let edge_amp = rng.uniform(-0.6, 0.6);
        for r in 0..3 {
            for c in 0..3 {
                let mut v = level
                    + gx * (c as f64 - 1.0) / 4.0
                    + gy * (r as f64 - 1.0) / 4.0
                    + rng.normal_ms(0.0, 0.02);
                if c as f64 > edge_pos {
                    v += edge_amp;
                }
                out[r * 3 + c] = v.clamp(0.0, 1.0) as f32;
            }
        }
    }

    fn cpu_cycles(&self) -> u64 {
        // 18 MACs + sqrt + clamp.
        50
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_window_is_zero() {
        let b = Sobel;
        let mut y = [1.0f64];
        b.eval(&[0.7f32; 9], &mut y);
        assert!(y[0].abs() < 1e-12);
    }

    #[test]
    fn strong_vertical_edge_detected() {
        let b = Sobel;
        let w = [0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0f32];
        let mut y = [0.0f64];
        b.eval(&w, &mut y);
        assert!(y[0] > 0.5, "edge magnitude {y:?}");
    }

    #[test]
    fn output_in_unit_range() {
        let b = Sobel;
        let mut rng = Rng::new(12);
        for _ in 0..300 {
            let mut x = [0.0f32; 9];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64];
            b.eval(&x, &mut y);
            assert!((0.0..=1.0).contains(&y[0]));
        }
    }
}
