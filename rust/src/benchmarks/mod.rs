//! The eight PRECISE target functions (paper Fig. 6) — the "CPU" path the
//! coordinator falls back to when the classifier rejects a sample, plus
//! workload generators for serving-style traffic.
//!
//! Every function mirrors `python/compile/benchmarks.py` number-for-number
//! (same erf approximation, same quadrature nodes, same DCT/quant tables);
//! `tests/golden.rs` pins the agreement against `artifacts/golden.json`.

pub mod special;

mod bessel;
mod blackscholes;
mod fft;
mod inversek2j;
mod jmeint;
mod jpeg;
mod kmeans;
mod sobel;

pub use bessel::Bessel;
pub use blackscholes::BlackScholes;
pub use fft::Fft;
pub use inversek2j::InverseK2j;
pub use jmeint::Jmeint;
pub use jpeg::Jpeg;
pub use kmeans::Kmeans;
pub use sobel::Sobel;

use crate::util::rng::Rng;

/// A precise target function plus its input generator.
pub trait BenchFn: Send + Sync {
    /// Benchmark name (matches the manifest key).
    fn name(&self) -> &'static str;

    /// Raw input dimensionality.
    fn n_in(&self) -> usize;

    /// Raw output dimensionality.
    fn n_out(&self) -> usize;

    /// The precise computation on ONE raw input row (f64 internally —
    /// this is the ground truth everything is scored against).
    fn eval(&self, x_raw: &[f32], out: &mut [f64]);

    /// Draw one raw input row from the benchmark's input distribution
    /// (used by the serving examples; offline eval reads `test.bin`).
    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]);

    /// Estimated CPU cost of one precise evaluation, in cycles, for the
    /// NPU simulator's speedup/energy model (DESIGN.md lists the op-count
    /// derivations; see also `npu::cpu_model`).
    fn cpu_cycles(&self) -> u64;
}

/// Registry of all benchmarks.
pub fn all() -> Vec<Box<dyn BenchFn>> {
    vec![
        Box::new(BlackScholes),
        Box::new(Fft),
        Box::new(InverseK2j),
        Box::new(Jmeint),
        Box::new(Jpeg),
        Box::new(Kmeans),
        Box::new(Sobel),
        Box::new(Bessel),
    ]
}

/// Look a benchmark up by name.
pub fn by_name(name: &str) -> crate::Result<Box<dyn BenchFn>> {
    all()
        .into_iter()
        .find(|b| b.name() == name)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark {name:?}"))
}

/// Evaluate a whole batch of raw rows into a normalised output buffer,
/// given the manifest normalisation bounds.
pub fn eval_batch_normalized(
    bench: &dyn BenchFn,
    man: &crate::formats::BenchManifest,
    x_raw: &[f32],
    n: usize,
) -> Vec<f32> {
    let (d_in, d_out) = (bench.n_in(), bench.n_out());
    assert_eq!(x_raw.len(), n * d_in);
    let mut out = vec![0.0f32; n * d_out];
    let mut raw = vec![0.0f64; d_out];
    for i in 0..n {
        bench.eval(&x_raw[i * d_in..(i + 1) * d_in], &mut raw);
        man.normalize_y_into(&raw, &mut out[i * d_out..(i + 1) * d_out]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_the_paper_suite() {
        let names: Vec<&str> = all().iter().map(|b| b.name()).collect();
        assert_eq!(
            names,
            vec!["blackscholes", "fft", "inversek2j", "jmeint", "jpeg", "kmeans", "sobel", "bessel"]
        );
    }

    #[test]
    fn generators_match_declared_dims() {
        let mut rng = Rng::new(1);
        for b in all() {
            let mut x = vec![0.0f32; b.n_in()];
            let mut y = vec![0.0f64; b.n_out()];
            b.gen_into(&mut rng, &mut x);
            b.eval(&x, &mut y);
            assert!(y.iter().all(|v| v.is_finite()), "{} non-finite", b.name());
            assert!(b.cpu_cycles() > 0);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(by_name("sobel").is_ok());
        assert!(by_name("nope").is_err());
    }
}
