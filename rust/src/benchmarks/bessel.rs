//! Bessel function of the first kind J_nu(x) (Scientific Computing, 2 -> 1)
//! for real order nu in [0, 4], x in [0.5, 15] — the GSL-derived benchmark
//! the paper visualises in Figs. 9-11.  Computed by the same fixed-node
//! Simpson quadrature as the Python side (`special::bessel_j`), so both
//! languages define the identical target function.

use super::special::bessel_j;
use super::BenchFn;
use crate::util::rng::Rng;

pub struct Bessel;

impl BenchFn for Bessel {
    fn name(&self) -> &'static str {
        "bessel"
    }

    fn n_in(&self) -> usize {
        2
    }

    fn n_out(&self) -> usize {
        1
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        out[0] = bessel_j(x[0] as f64, x[1] as f64);
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        out[0] = rng.uniform(0.0, 4.0) as f32;
        out[1] = rng.uniform(0.5, 15.0) as f32;
    }

    fn cpu_cycles(&self) -> u64 {
        // 97 + 121 quadrature nodes, each with sin/cos/sinh/exp (~40 cyc).
        9000
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_special_fn() {
        let b = Bessel;
        let mut y = [0.0f64];
        b.eval(&[1.0, 1.0], &mut y);
        assert!((y[0] - 0.4400505857).abs() < 1e-7);
    }

    #[test]
    fn bounded_amplitude_on_domain() {
        // |J_nu(x)| <= 1 for nu >= 0.
        let b = Bessel;
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            let mut x = [0.0f32; 2];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64];
            b.eval(&x, &mut y);
            assert!(y[0].abs() <= 1.0 + 1e-9, "J_{}({}) = {}", x[0], x[1], y[0]);
        }
    }
}
