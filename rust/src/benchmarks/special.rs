//! Shared deterministic special functions, mirroring
//! `python/compile/benchmarks.py` exactly (same constants, same quadrature
//! nodes) so both languages compute the *identical* target function.

use std::f64::consts::PI;

const ERF_A: [f64; 5] = [0.254829592, -0.284496736, 1.421413741, -1.453152027, 1.061405429];
const ERF_P: f64 = 0.3275911;

/// Abramowitz–Stegun 7.1.26 rational erf approximation (|err| < 1.5e-7).
pub fn erf_as(x: f64) -> f64 {
    if x == 0.0 {
        return 0.0; // numpy sign(0) == 0; keep bit-identical to Python
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let ax = x.abs();
    let t = 1.0 / (1.0 + ERF_P * ax);
    let poly = t * (ERF_A[0] + t * (ERF_A[1] + t * (ERF_A[2] + t * (ERF_A[3] + t * ERF_A[4]))));
    sign * (1.0 - poly * (-ax * ax).exp())
}

/// Standard normal CDF via `erf_as`.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf_as(x / std::f64::consts::SQRT_2))
}

// Simpson quadrature parameters — MUST match benchmarks.py.
const BESSEL_N1: usize = 96;
const BESSEL_N2: usize = 120;
const BESSEL_S_MAX: f64 = 6.0;

/// Deterministic J_nu(x) via fixed-node Simpson quadrature;
/// valid for nu in [0, 4], x in [0.5, 15] (the benchmark domain).
pub fn bessel_j(nu: f64, x: f64) -> f64 {
    // First integral: (1/pi) ∫_0^pi cos(nu*t - x*sin t) dt.
    let h1 = PI / BESSEL_N1 as f64;
    let mut term1 = 0.0;
    for k in 0..=BESSEL_N1 {
        let t = k as f64 * h1;
        let w = simpson_weight(k, BESSEL_N1) * (h1 / 3.0);
        term1 += w * (nu * t - x * t.sin()).cos();
    }
    term1 /= PI;

    // Second integral: sin(nu*pi)/pi ∫_0^smax exp(-x*sinh s - nu*s) ds.
    let h2 = BESSEL_S_MAX / BESSEL_N2 as f64;
    let mut term2 = 0.0;
    for k in 0..=BESSEL_N2 {
        let s = k as f64 * h2;
        let w = simpson_weight(k, BESSEL_N2) * (h2 / 3.0);
        term2 += w * (-x * s.sinh() - nu * s).exp();
    }
    term2 *= (nu * PI).sin() / PI;

    term1 - term2
}

#[inline]
fn simpson_weight(k: usize, n: usize) -> f64 {
    if k == 0 || k == n {
        1.0
    } else if k % 2 == 1 {
        4.0
    } else {
        2.0
    }
}

// ---------------------------------------------------------------------------
// 8x8 DCT machinery for the jpeg benchmark.
// ---------------------------------------------------------------------------

/// Standard JPEG luminance quantisation table (quality 50), row-major.
pub const JPEG_QTABLE: [f64; 64] = [
    16.0, 11.0, 10.0, 16.0, 24.0, 40.0, 51.0, 61.0,
    12.0, 12.0, 14.0, 19.0, 26.0, 58.0, 60.0, 55.0,
    14.0, 13.0, 16.0, 24.0, 40.0, 57.0, 69.0, 56.0,
    14.0, 17.0, 22.0, 29.0, 51.0, 87.0, 80.0, 62.0,
    18.0, 22.0, 37.0, 56.0, 68.0, 109.0, 103.0, 77.0,
    24.0, 35.0, 55.0, 64.0, 81.0, 104.0, 113.0, 92.0,
    49.0, 64.0, 78.0, 87.0, 103.0, 121.0, 120.0, 101.0,
    72.0, 92.0, 95.0, 98.0, 112.0, 100.0, 103.0, 99.0,
];

/// Orthonormal DCT-II basis matrix C (8x8): X = C x C^T.
pub fn dct8_matrix() -> [[f64; 8]; 8] {
    let mut c = [[0.0; 8]; 8];
    for (k, row) in c.iter_mut().enumerate() {
        let alpha = if k == 0 { (1.0f64 / 8.0).sqrt() } else { (2.0f64 / 8.0).sqrt() };
        for (n, v) in row.iter_mut().enumerate() {
            *v = alpha * (PI * (2 * n + 1) as f64 * k as f64 / 16.0).cos();
        }
    }
    c
}

/// DCT -> quantise -> dequantise -> IDCT on one 8x8 block of [0,1] pixels.
pub fn jpeg_roundtrip_block(pixels: &[f32; 64]) -> [f64; 64] {
    let c = dct8_matrix();
    // Center to [-128, 127].
    let mut b = [[0.0f64; 8]; 8];
    for r in 0..8 {
        for cc in 0..8 {
            b[r][cc] = pixels[r * 8 + cc] as f64 * 255.0 - 128.0;
        }
    }
    // coef = C b C^T
    let mut tmp = [[0.0f64; 8]; 8];
    for i in 0..8 {
        for j in 0..8 {
            let mut s = 0.0;
            for k in 0..8 {
                s += c[i][k] * b[k][j];
            }
            tmp[i][j] = s;
        }
    }
    let mut coef = [[0.0f64; 8]; 8];
    for i in 0..8 {
        for j in 0..8 {
            let mut s = 0.0;
            for k in 0..8 {
                s += tmp[i][k] * c[j][k];
            }
            coef[i][j] = s;
        }
    }
    // Quantise / dequantise.
    for i in 0..8 {
        for j in 0..8 {
            let q = JPEG_QTABLE[i * 8 + j];
            coef[i][j] = (coef[i][j] / q).round() * q;
        }
    }
    // rec = C^T coef C
    for i in 0..8 {
        for j in 0..8 {
            let mut s = 0.0;
            for k in 0..8 {
                s += c[k][i] * coef[k][j];
            }
            tmp[i][j] = s;
        }
    }
    let mut out = [0.0f64; 64];
    for i in 0..8 {
        for j in 0..8 {
            let mut s = 0.0;
            for k in 0..8 {
                s += tmp[i][k] * c[k][j];
            }
            out[i * 8 + j] = ((s + 128.0) / 255.0).clamp(0.0, 1.0);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Triangle-triangle intersection (separating-axis test), matching
// benchmarks.py::_tri_tri_overlap_one.
// ---------------------------------------------------------------------------

type V3 = [f64; 3];

fn sub(a: V3, b: V3) -> V3 {
    [a[0] - b[0], a[1] - b[1], a[2] - b[2]]
}

fn cross(a: V3, b: V3) -> V3 {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

fn dot(a: V3, b: V3) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

/// SAT 3-D triangle intersection; `p`/`q` are 3 vertex rows each.
pub fn tri_tri_overlap(p: &[V3; 3], q: &[V3; 3]) -> bool {
    let e_p = [sub(p[1], p[0]), sub(p[2], p[1]), sub(p[0], p[2])];
    let e_q = [sub(q[1], q[0]), sub(q[2], q[1]), sub(q[0], q[2])];
    let mut axes: Vec<V3> = Vec::with_capacity(11);
    axes.push(cross(e_p[0], e_p[1]));
    axes.push(cross(e_q[0], e_q[1]));
    for a in &e_p {
        for b in &e_q {
            axes.push(cross(*a, *b));
        }
    }
    for ax in axes {
        let n2 = dot(ax, ax);
        if n2 < 1e-12 {
            continue;
        }
        let dp: Vec<f64> = p.iter().map(|v| dot(*v, ax)).collect();
        let dq: Vec<f64> = q.iter().map(|v| dot(*v, ax)).collect();
        let (p_min, p_max) = min_max(&dp);
        let (q_min, q_max) = min_max(&dq);
        if p_max < q_min - 1e-12 || q_max < p_min - 1e-12 {
            return false;
        }
    }
    true
}

fn min_max(xs: &[f64]) -> (f64, f64) {
    let mut lo = xs[0];
    let mut hi = xs[0];
    for &x in &xs[1..] {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf_as(0.0)).abs() < 1e-12);
        assert!((erf_as(1.0) - 0.8427007).abs() < 1e-5);
        assert!((erf_as(-1.0) + 0.8427007).abs() < 1e-5);
        assert!((erf_as(3.0) - 0.99997791).abs() < 1e-5);
    }

    #[test]
    fn bessel_known_values() {
        // First zero of J_0.
        assert!(bessel_j(0.0, 2.404825557695773).abs() < 1e-6);
        assert!((bessel_j(0.0, 1.0) - 0.7651976866).abs() < 1e-7);
        assert!((bessel_j(1.0, 1.0) - 0.4400505857).abs() < 1e-7);
        assert!((bessel_j(2.0, 5.0) - 0.04656511628).abs() < 1e-7);
    }

    #[test]
    fn dct_matrix_orthonormal() {
        let c = dct8_matrix();
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0;
                for k in 0..8 {
                    s += c[i][k] * c[j][k];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-12, "({i},{j}) -> {s}");
            }
        }
    }

    #[test]
    fn jpeg_flat_block_identity() {
        let level = 128.0 / 255.0;
        let block = [level as f32; 64];
        let out = jpeg_roundtrip_block(&block);
        for v in out {
            assert!((v - level).abs() < 1e-6);
        }
    }

    #[test]
    fn tri_tri_basic_cases() {
        let t: [V3; 3] = [[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 1.0, 0.0]];
        assert!(tri_tri_overlap(&t, &t));
        let far: [V3; 3] = [[10.0, 10.0, 10.0], [11.0, 10.0, 10.0], [10.0, 11.0, 10.0]];
        assert!(!tri_tri_overlap(&t, &far));
        let pierce: [V3; 3] = [[0.25, 0.25, -1.0], [0.25, 0.25, 1.0], [1.0, 1.0, 1.0]];
        assert!(tri_tri_overlap(&t, &pierce));
    }
}
