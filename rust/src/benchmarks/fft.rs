//! FFT twiddle factor (Signal Processing, 1 -> 2): x -> (cos x, sin x).
//! The paper treats this benchmark as "not suitable for approximation";
//! it exists to show all methods degrade to zero invocation gracefully.

use super::BenchFn;
use crate::util::rng::Rng;

pub struct Fft;

impl BenchFn for Fft {
    fn name(&self) -> &'static str {
        "fft"
    }

    fn n_in(&self) -> usize {
        1
    }

    fn n_out(&self) -> usize {
        2
    }

    fn eval(&self, x: &[f32], out: &mut [f64]) {
        let a = x[0] as f64;
        out[0] = a.cos();
        out[1] = a.sin();
    }

    fn gen_into(&self, rng: &mut Rng, out: &mut [f32]) {
        out[0] = rng.uniform(0.0, 2.0 * std::f64::consts::PI) as f32;
    }

    fn cpu_cycles(&self) -> u64 {
        // sincos pair; cheap.
        60
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_unit_circle() {
        let b = Fft;
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let mut x = [0.0f32; 1];
            b.gen_into(&mut rng, &mut x);
            let mut y = [0.0f64; 2];
            b.eval(&x, &mut y);
            assert!((y[0] * y[0] + y[1] * y[1] - 1.0).abs() < 1e-12);
        }
    }
}
