//! Cycle-level NPU simulator + energy model (paper §III.D, Fig. 5; used to
//! regenerate Fig. 8's speedup / energy-reduction bars).
//!
//! The microarchitecture follows the NPU of Esmaeilzadeh et al. [10] that
//! the paper builds on: tiles of PEs behind input/output FIFOs and an
//! internal bus; each PE computes one neuron at a time (a MAC loop over the
//! fan-in, then the activation unit); weights live in per-PE buffers fed
//! from an on-chip cache.  The MCMA extension is a controller that reads
//! the classifier's prediction and switches the approximator weight set
//! (`coordinator::WeightCache` models the §III.D residency cases).
//!
//! The paper estimates MCMA performance "by scaling the performance of NPU
//! in [10] based on the invocation"; this simulator reproduces that scaling
//! law from explicit per-layer cycle counts instead of a single scalar, so
//! batching, topology and weight-switch effects are all first-class.

pub mod cost;
pub mod energy;

pub use cost::{mlp_cost, LayerCost, MlpCost};
pub use energy::EnergyModel;

use crate::config::NpuConfig;
use crate::coordinator::{BufferCase, Route, WeightCache};

/// Result of simulating one routed trace.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub n: usize,
    /// Total cycles with the approximate pipeline (classifier on NPU for
    /// every sample; approximator or CPU per routing).
    pub cycles: f64,
    /// Total cycles if every sample ran precisely on the CPU (the paper's
    /// "CPU only" baseline).
    pub cycles_cpu_only: f64,
    /// Energy (pJ) with the approximate pipeline.
    pub energy_pj: f64,
    /// Energy (pJ) CPU-only.
    pub energy_cpu_only_pj: f64,
    /// Cycle breakdown.
    pub cycles_classifier: f64,
    pub cycles_approx: f64,
    pub cycles_cpu_fallback: f64,
    pub cycles_weight_switch: f64,
    pub weight_switches: u64,
}

impl SimResult {
    /// Speedup over running everything on the CPU.
    pub fn speedup_vs_cpu(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.cycles_cpu_only / self.cycles
        }
    }

    /// Energy reduction over CPU-only.
    pub fn energy_reduction_vs_cpu(&self) -> f64 {
        if self.energy_pj == 0.0 {
            0.0
        } else {
            self.energy_cpu_only_pj / self.energy_pj
        }
    }
}

/// The simulator: NPU config + per-net costs, applied to a routing trace.
pub struct NpuSim {
    pub cfg: NpuConfig,
    pub clf_cost: MlpCost,
    pub approx_costs: Vec<MlpCost>,
    /// Precise CPU cycles per sample for this benchmark.
    pub cpu_cycles: u64,
    energy: EnergyModel,
}

impl NpuSim {
    pub fn new(
        cfg: NpuConfig,
        clf_topology: &[usize],
        approx_topologies: &[Vec<usize>],
        cpu_cycles: u64,
    ) -> Self {
        let clf_cost = mlp_cost(&cfg, clf_topology);
        let approx_costs = approx_topologies.iter().map(|t| mlp_cost(&cfg, t)).collect();
        let energy = EnergyModel::new(cfg);
        NpuSim { cfg, clf_cost, approx_costs, cpu_cycles, energy }
    }

    /// Simulate a routed trace in arrival order.  `force_case` overrides
    /// the weight-buffer residency classification (ablations).
    pub fn simulate(&self, routes: &[Route], force_case: Option<BufferCase>) -> SimResult {
        let words: Vec<usize> = self.approx_costs.iter().map(|c| c.weight_words).collect();
        let mut wc = WeightCache::new(&self.cfg, words);
        if let Some(case) = force_case {
            wc.force_case(case);
        }
        let stream_weights = wc.case() == BufferCase::StreamAlways;

        let mut r = SimResult { n: routes.len(), ..Default::default() };
        for route in routes {
            // The classifier screens EVERY sample on the NPU (Fig. 5 stages
            // 1-3); this is the MCMA overhead one-pass also pays.
            r.cycles_classifier += self.clf_cost.cycles as f64;
            r.energy_pj += self.energy.mlp(&self.clf_cost);
            match route {
                Route::Approx(k) => {
                    let switch = wc.access(*k);
                    r.cycles_weight_switch += switch as f64;
                    let cost = &self.approx_costs[*k];
                    let mut cyc = cost.cycles;
                    if stream_weights {
                        cyc += cost.stream_cycles;
                    }
                    r.cycles_approx += cyc as f64;
                    r.energy_pj += self.energy.mlp(cost)
                        + self.energy.weight_refill(switch, &self.cfg)
                        + self.cfg.e_invoke_pj;
                }
                Route::Cpu => {
                    r.cycles_cpu_fallback += self.cpu_cycles as f64 / self.cfg.clock_ratio;
                    r.energy_pj += self.cpu_cycles as f64 * self.cfg.e_cpu_cycle_pj;
                }
            }
            r.cycles_cpu_only += self.cpu_cycles as f64 / self.cfg.clock_ratio;
            r.energy_cpu_only_pj += self.cpu_cycles as f64 * self.cfg.e_cpu_cycle_pj;
        }
        r.weight_switches = wc.switches;
        r.cycles = r.cycles_classifier
            + r.cycles_approx
            + r.cycles_cpu_fallback
            + r.cycles_weight_switch;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routes(inv: usize, cpu: usize) -> Vec<Route> {
        let mut v = vec![Route::Approx(0); inv];
        v.extend(vec![Route::Cpu; cpu]);
        v
    }

    fn sim() -> NpuSim {
        NpuSim::new(
            NpuConfig::default(),
            &[6, 8, 2],
            &[vec![6, 8, 1], vec![6, 8, 1], vec![6, 8, 1]],
            2000,
        )
    }

    #[test]
    fn higher_invocation_higher_speedup() {
        let s = sim();
        let lo = s.simulate(&routes(100, 900), None);
        let hi = s.simulate(&routes(900, 100), None);
        assert!(hi.speedup_vs_cpu() > lo.speedup_vs_cpu(),
            "hi {} <= lo {}", hi.speedup_vs_cpu(), lo.speedup_vs_cpu());
        assert!(hi.energy_reduction_vs_cpu() > lo.energy_reduction_vs_cpu());
    }

    #[test]
    fn all_cpu_slower_than_cpu_only() {
        // Classifier screening makes the approximate pipeline strictly
        // worse when nothing is ever invoked.
        let s = sim();
        let r = s.simulate(&routes(0, 500), None);
        assert!(r.speedup_vs_cpu() < 1.0);
        assert_eq!(r.weight_switches, 0);
    }

    #[test]
    fn all_invoked_beats_cpu_when_npu_cheaper() {
        let s = sim();
        let r = s.simulate(&routes(1000, 0), None);
        assert!(r.speedup_vs_cpu() > 1.0, "speedup {}", r.speedup_vs_cpu());
        assert!(r.energy_reduction_vs_cpu() > 1.0);
    }

    #[test]
    fn alternating_routes_charge_switches_in_case3() {
        let mut cfg = NpuConfig::default();
        cfg.weight_buffer_words = 16; // tiny: only one approximator resident
        let s = NpuSim::new(cfg, &[6, 8, 2],
                            &[vec![6, 8, 1], vec![6, 8, 1]], 2000);
        let trace: Vec<Route> =
            (0..100).map(|i| Route::Approx(i % 2)).collect();
        let r = s.simulate(&trace, None);
        assert_eq!(r.weight_switches, 100);
        assert!(r.cycles_weight_switch > 0.0);
        let forced = s.simulate(&trace, Some(BufferCase::AllResident));
        assert_eq!(forced.weight_switches, 0);
        assert!(forced.cycles < r.cycles);
    }

    #[test]
    fn cycles_decompose() {
        let s = sim();
        let r = s.simulate(&routes(300, 200), None);
        let sum = r.cycles_classifier + r.cycles_approx + r.cycles_cpu_fallback
            + r.cycles_weight_switch;
        assert!((r.cycles - sum).abs() < 1e-9);
        assert_eq!(r.n, 500);
    }
}
