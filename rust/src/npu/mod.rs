//! Cycle-level NPU simulator + energy model (paper §III.D, Fig. 5; used to
//! regenerate Fig. 8's speedup / energy-reduction bars).
//!
//! The microarchitecture follows the NPU of Esmaeilzadeh et al. [10] that
//! the paper builds on: tiles of PEs behind input/output FIFOs and an
//! internal bus; each PE computes one neuron at a time (a MAC loop over the
//! fan-in, then the activation unit); weights live in per-PE buffers fed
//! from an on-chip cache.  The MCMA extension is a controller that reads
//! the classifier's prediction and switches the approximator weight set
//! (`coordinator::WeightCache` models the §III.D residency cases).
//!
//! The paper estimates MCMA performance "by scaling the performance of NPU
//! in [10] based on the invocation"; this simulator reproduces that scaling
//! law from explicit per-layer cycle counts instead of a single scalar, so
//! batching, topology and weight-switch effects are all first-class.

pub mod cost;
pub mod energy;

pub use cost::{mlp_cost, mlp_cost_prec, LayerCost, MlpCost};
pub use energy::EnergyModel;

use crate::config::{NpuConfig, Precision};
use crate::coordinator::{BufferCase, Route, WeightCache};

/// Result of simulating one routed trace.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub n: usize,
    /// Total cycles with the approximate pipeline (classifier on NPU for
    /// every sample; approximator or CPU per routing).
    pub cycles: f64,
    /// Total cycles if every sample ran precisely on the CPU (the paper's
    /// "CPU only" baseline).
    pub cycles_cpu_only: f64,
    /// Energy (pJ) with the approximate pipeline.
    pub energy_pj: f64,
    /// Energy (pJ) CPU-only.
    pub energy_cpu_only_pj: f64,
    /// Cycle breakdown.
    pub cycles_classifier: f64,
    pub cycles_approx: f64,
    pub cycles_cpu_fallback: f64,
    pub cycles_weight_switch: f64,
    pub weight_switches: u64,
}

impl SimResult {
    /// Speedup over running everything on the CPU.
    pub fn speedup_vs_cpu(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.cycles_cpu_only / self.cycles
        }
    }

    /// Energy reduction over CPU-only.
    pub fn energy_reduction_vs_cpu(&self) -> f64 {
        if self.energy_pj == 0.0 {
            0.0
        } else {
            self.energy_cpu_only_pj / self.energy_pj
        }
    }
}

/// The simulator: NPU config + per-net costs, applied to a routing trace.
pub struct NpuSim {
    pub cfg: NpuConfig,
    pub clf_cost: MlpCost,
    pub approx_costs: Vec<MlpCost>,
    /// Precise CPU cycles per sample for this benchmark.
    pub cpu_cycles: u64,
    /// MAC datapath precision the per-net costs were derived for.
    pub precision: Precision,
    clf_topology: Vec<usize>,
    approx_topologies: Vec<Vec<usize>>,
    energy: EnergyModel,
}

impl NpuSim {
    pub fn new(
        cfg: NpuConfig,
        clf_topology: &[usize],
        approx_topologies: &[Vec<usize>],
        cpu_cycles: u64,
    ) -> Self {
        let clf_cost = mlp_cost(&cfg, clf_topology);
        let approx_costs = approx_topologies.iter().map(|t| mlp_cost(&cfg, t)).collect();
        let energy = EnergyModel::new(cfg);
        NpuSim {
            cfg,
            clf_cost,
            approx_costs,
            cpu_cycles,
            precision: Precision::F32,
            clf_topology: clf_topology.to_vec(),
            approx_topologies: approx_topologies.to_vec(),
            energy,
        }
    }

    /// Re-derive every per-net cost for `prec` (int8 MACs are faster and
    /// cheaper; quantized weights pack 4 per word, so more approximators
    /// fit resident and refills stream 4x faster).
    pub fn with_precision(mut self, prec: Precision) -> Self {
        self.precision = prec;
        self.clf_cost = mlp_cost_prec(&self.cfg, &self.clf_topology, prec);
        self.approx_costs = self
            .approx_topologies
            .iter()
            .map(|t| mlp_cost_prec(&self.cfg, t, prec))
            .collect();
        self
    }

    /// Simulate a routed trace in arrival order.  `force_case` overrides
    /// the weight-buffer residency classification (ablations).
    pub fn simulate(&self, routes: &[Route], force_case: Option<BufferCase>) -> SimResult {
        // Buffer residency and refill cost are charged in f32-word units:
        // int8 weights occupy a quarter word each.
        let vpw = self.precision.values_per_word() as usize;
        let words: Vec<usize> = self
            .approx_costs
            .iter()
            .map(|c| c.weight_words.div_ceil(vpw))
            .collect();
        let mut wc = WeightCache::new(&self.cfg, words);
        if let Some(case) = force_case {
            wc.force_case(case);
        }
        let stream_weights = wc.case() == BufferCase::StreamAlways;

        let mut r = SimResult { n: routes.len(), ..Default::default() };
        for route in routes {
            // The classifier screens EVERY sample on the NPU (Fig. 5 stages
            // 1-3); this is the MCMA overhead one-pass also pays.
            r.cycles_classifier += self.clf_cost.cycles as f64;
            r.energy_pj += self.energy.mlp(&self.clf_cost);
            match route {
                Route::Approx(k) => {
                    let switch = wc.access(*k);
                    r.cycles_weight_switch += switch as f64;
                    let cost = &self.approx_costs[*k];
                    let mut cyc = cost.cycles;
                    if stream_weights {
                        cyc += cost.stream_cycles;
                    }
                    r.cycles_approx += cyc as f64;
                    r.energy_pj += self.energy.mlp(cost)
                        + self.energy.weight_refill(switch, &self.cfg)
                        + self.cfg.e_invoke_pj;
                }
                Route::Cpu => {
                    r.cycles_cpu_fallback += self.cpu_cycles as f64 / self.cfg.clock_ratio;
                    r.energy_pj += self.cpu_cycles as f64 * self.cfg.e_cpu_cycle_pj;
                }
            }
            r.cycles_cpu_only += self.cpu_cycles as f64 / self.cfg.clock_ratio;
            r.energy_cpu_only_pj += self.cpu_cycles as f64 * self.cfg.e_cpu_cycle_pj;
        }
        r.weight_switches = wc.switches;
        r.cycles = r.cycles_classifier
            + r.cycles_approx
            + r.cycles_cpu_fallback
            + r.cycles_weight_switch;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn routes(inv: usize, cpu: usize) -> Vec<Route> {
        let mut v = vec![Route::Approx(0); inv];
        v.extend(vec![Route::Cpu; cpu]);
        v
    }

    fn sim() -> NpuSim {
        NpuSim::new(
            NpuConfig::default(),
            &[6, 8, 2],
            &[vec![6, 8, 1], vec![6, 8, 1], vec![6, 8, 1]],
            2000,
        )
    }

    #[test]
    fn higher_invocation_higher_speedup() {
        let s = sim();
        let lo = s.simulate(&routes(100, 900), None);
        let hi = s.simulate(&routes(900, 100), None);
        assert!(hi.speedup_vs_cpu() > lo.speedup_vs_cpu(),
            "hi {} <= lo {}", hi.speedup_vs_cpu(), lo.speedup_vs_cpu());
        assert!(hi.energy_reduction_vs_cpu() > lo.energy_reduction_vs_cpu());
    }

    #[test]
    fn all_cpu_slower_than_cpu_only() {
        // Classifier screening makes the approximate pipeline strictly
        // worse when nothing is ever invoked.
        let s = sim();
        let r = s.simulate(&routes(0, 500), None);
        assert!(r.speedup_vs_cpu() < 1.0);
        assert_eq!(r.weight_switches, 0);
    }

    #[test]
    fn all_invoked_beats_cpu_when_npu_cheaper() {
        let s = sim();
        let r = s.simulate(&routes(1000, 0), None);
        assert!(r.speedup_vs_cpu() > 1.0, "speedup {}", r.speedup_vs_cpu());
        assert!(r.energy_reduction_vs_cpu() > 1.0);
    }

    #[test]
    fn alternating_routes_charge_switches_in_case3() {
        let mut cfg = NpuConfig::default();
        cfg.weight_buffer_words = 16; // tiny: only one approximator resident
        let s = NpuSim::new(cfg, &[6, 8, 2],
                            &[vec![6, 8, 1], vec![6, 8, 1]], 2000);
        let trace: Vec<Route> =
            (0..100).map(|i| Route::Approx(i % 2)).collect();
        let r = s.simulate(&trace, None);
        assert_eq!(r.weight_switches, 100);
        assert!(r.cycles_weight_switch > 0.0);
        let forced = s.simulate(&trace, Some(BufferCase::AllResident));
        assert_eq!(forced.weight_switches, 0);
        assert!(forced.cycles < r.cycles);
    }

    /// Int8 precision never worsens the simulated pipeline: cycles and
    /// energy both drop (or tie) for the same routing trace, so fig8-style
    /// numbers reflect quantization.
    #[test]
    fn int8_precision_improves_speedup_and_energy() {
        let s32 = sim();
        let s8 = sim().with_precision(Precision::Int8);
        let trace = routes(700, 300);
        let r32 = s32.simulate(&trace, None);
        let r8 = s8.simulate(&trace, None);
        assert!(r8.cycles <= r32.cycles, "int8 {} > f32 {}", r8.cycles, r32.cycles);
        assert!(r8.energy_pj < r32.energy_pj, "int8 {} !< f32 {}", r8.energy_pj, r32.energy_pj);
        assert!(r8.speedup_vs_cpu() >= r32.speedup_vs_cpu());
        assert!(r8.energy_reduction_vs_cpu() > r32.energy_reduction_vs_cpu());
        // CPU-only baseline is precision-independent.
        assert_eq!(r8.cycles_cpu_only, r32.cycles_cpu_only);
        assert_eq!(r8.energy_cpu_only_pj, r32.energy_cpu_only_pj);
    }

    /// Quartered weight residency can flip §III.D Case 3 into Case 1:
    /// a buffer that holds only one f32 approximator holds all of their
    /// int8 twins.
    #[test]
    fn int8_residency_flips_case3_to_case1() {
        let cfg = NpuConfig { weight_buffer_words: 80, pes_per_tile: 1, ..Default::default() };
        // Two approximators of 71 weight words each: f32 -> only one fits
        // (Case 3: 71 <= 80 < 142); int8 -> ceil(71/4)=18 words each, both
        // fit (Case 1).
        let s = NpuSim::new(cfg, &[6, 8, 2], &[vec![8, 7, 1], vec![8, 7, 1]], 2000);
        let trace: Vec<Route> = (0..50).map(|i| Route::Approx(i % 2)).collect();
        let f32_run = s.simulate(&trace, None);
        assert!(f32_run.weight_switches > 0, "expected Case-3 switches at f32");
        let q8_run = s.with_precision(Precision::Int8).simulate(&trace, None);
        assert_eq!(q8_run.weight_switches, 0, "int8 twins should be all-resident");
    }

    #[test]
    fn cycles_decompose() {
        let s = sim();
        let r = s.simulate(&routes(300, 200), None);
        let sum = r.cycles_classifier + r.cycles_approx + r.cycles_cpu_fallback
            + r.cycles_weight_switch;
        assert!((r.cycles - sum).abs() < 1e-9);
        assert_eq!(r.n, 500);
    }
}
