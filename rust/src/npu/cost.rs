//! Per-MLP cycle cost derivation on the tile-of-PEs microarchitecture.
//!
//! One layer `(fan_in -> fan_out)` executes as:
//!   * the bus streams `fan_in` input words from the input FIFO to the PEs
//!     (`fan_in / bus_words_per_cycle` cycles, overlapped per pass);
//!   * PEs compute neurons in parallel: `ceil(fan_out / n_pes)` passes,
//!     each pass = `ceil(fan_in / macs_per_pe_cycle)` MAC cycles + the
//!     activation-unit latency;
//!   * `fan_out` results stream to the output FIFO.
//!
//! The per-layer cycle count is `max(compute, io)` — the bus and the MAC
//! array overlap (Fig. 5's FIFO decoupling) — summed over layers.  In
//! Case 2 (weights never resident) add `weight_words / refill_bw` streaming
//! cycles per inference (`stream_cycles`).
//!
//! Precision: [`mlp_cost_prec`] derives the same counts for the int8
//! datapath — each PE retires `q8_macs_per_pe_cycle` narrow MACs per cycle
//! and the bus/cache move `Precision::values_per_word` packed values per
//! word, so both compute and IO cycles shrink.  MAC/word *counts* stay
//! precision-independent (they are workload properties); the energy model
//! applies the per-precision constants.

use crate::config::{NpuConfig, Precision};

/// Cycle/energy-relevant counts for one layer.
#[derive(Clone, Copy, Debug)]
pub struct LayerCost {
    pub fan_in: usize,
    pub fan_out: usize,
    pub macs: u64,
    pub compute_cycles: u64,
    pub io_cycles: u64,
    pub cycles: u64,
}

/// Whole-net cost.
#[derive(Clone, Debug, Default)]
pub struct MlpCost {
    pub layers: Vec<LayerCost>,
    /// Total pipeline cycles for one inference (sample-at-a-time, as the
    /// NPU of [10] executes).
    pub cycles: u64,
    pub macs: u64,
    /// Words moved over the internal bus (inputs + outputs per layer).
    pub bus_words: u64,
    /// Total weight words (buffer residency / refill cost).
    pub weight_words: usize,
    /// Extra cycles per inference when weights must stream from cache
    /// (§III.D Case 2).
    pub stream_cycles: u64,
    /// Datapath precision the cycle counts were derived for.
    pub precision: Precision,
}

/// Derive the cost of an MLP topology on `cfg`'s tile (f32 datapath).
pub fn mlp_cost(cfg: &NpuConfig, topology: &[usize]) -> MlpCost {
    mlp_cost_prec(cfg, topology, Precision::F32)
}

/// [`mlp_cost`] for an explicit datapath precision.
pub fn mlp_cost_prec(cfg: &NpuConfig, topology: &[usize], prec: Precision) -> MlpCost {
    assert!(topology.len() >= 2, "topology needs at least in+out");
    let n_pes = (cfg.pes_per_tile * cfg.n_tiles).max(1) as u64;
    let mac_rate = match prec {
        Precision::F32 => cfg.macs_per_pe_cycle,
        Precision::Int8 => cfg.q8_macs_per_pe_cycle,
    }
    .max(1);
    let vpw = prec.values_per_word();
    let mut out = MlpCost { precision: prec, ..Default::default() };
    for w in topology.windows(2) {
        let (fan_in, fan_out) = (w[0], w[1]);
        let macs = (fan_in * fan_out) as u64;
        let passes = (fan_out as u64).div_ceil(n_pes);
        let mac_cycles = (fan_in as u64).div_ceil(mac_rate);
        let compute = passes * (mac_cycles + cfg.act_latency);
        let io = ((fan_in + fan_out) as u64).div_ceil(cfg.bus_words_per_cycle * vpw);
        let cycles = compute.max(io);
        out.layers.push(LayerCost {
            fan_in,
            fan_out,
            macs,
            compute_cycles: compute,
            io_cycles: io,
            cycles,
        });
        out.cycles += cycles;
        out.macs += macs;
        out.bus_words += (fan_in + fan_out) as u64;
        out.weight_words += fan_in * fan_out + fan_out;
    }
    out.stream_cycles =
        (out.weight_words as u64).div_ceil(cfg.cache_refill_words_per_cycle.max(1) * vpw);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn hand_checked_small_layer() {
        // 1 tile x 8 PEs, 1 MAC/cycle, act 2, bus 4.
        let cfg = NpuConfig { n_tiles: 1, ..Default::default() };
        let c = mlp_cost(&cfg, &[6, 8, 1]);
        // Layer 1: 8 neurons on 8 PEs = 1 pass x (6 + 2) = 8 compute;
        // io = ceil((6+8)/4) = 4 -> 8 cycles.
        assert_eq!(c.layers[0].cycles, 8);
        // Layer 2: 1 neuron, 1 pass x (8 + 2) = 10; io = ceil(9/4) = 3 -> 10.
        assert_eq!(c.layers[1].cycles, 10);
        assert_eq!(c.cycles, 18);
        assert_eq!(c.macs, 48 + 8);
        assert_eq!(c.weight_words, 6 * 8 + 8 + 8 + 1);
    }

    /// Int8 never costs more cycles than f32 on the same topology, and the
    /// MAC/word counts (workload properties) are precision-independent.
    #[test]
    fn int8_no_slower_and_counts_match() {
        let cfg = NpuConfig::default();
        for topo in [vec![6, 8, 1], vec![18, 32, 16, 2], vec![64, 16, 64]] {
            let f = mlp_cost_prec(&cfg, &topo, Precision::F32);
            let q = mlp_cost_prec(&cfg, &topo, Precision::Int8);
            assert!(q.cycles <= f.cycles, "{topo:?}: int8 {} > f32 {}", q.cycles, f.cycles);
            assert!(q.stream_cycles <= f.stream_cycles);
            assert_eq!(q.macs, f.macs);
            assert_eq!(q.bus_words, f.bus_words);
            assert_eq!(q.weight_words, f.weight_words);
            assert_eq!(q.precision, Precision::Int8);
            assert_eq!(f.precision, Precision::F32);
        }
        // With the default 4x MAC rate, a wide compute-bound layer gets a
        // real cycle reduction (not merely "no slower").
        let f = mlp_cost_prec(&cfg, &[64, 64, 64], Precision::F32);
        let q = mlp_cost_prec(&cfg, &[64, 64, 64], Precision::Int8);
        assert!(q.cycles < f.cycles, "int8 {} !< f32 {}", q.cycles, f.cycles);
    }

    #[test]
    fn more_pes_never_slower() {
        let a = mlp_cost(&NpuConfig { pes_per_tile: 2, ..Default::default() }, &[64, 16, 64]);
        let b = mlp_cost(&NpuConfig { pes_per_tile: 32, ..Default::default() }, &[64, 16, 64]);
        assert!(b.cycles <= a.cycles);
    }

    /// Properties: cycles bounded below by both pure-compute and pure-IO;
    /// MAC count is exactly sum(fan_in*fan_out); costs are monotone in
    /// topology width.
    #[test]
    fn prop_cost_invariants() {
        prop::check(
            "mlp-cost-invariants",
            200,
            0x57A75,
            |r: &mut Rng| {
                let depth = 2 + r.below(3) as usize;
                let topo: Vec<usize> =
                    (0..depth).map(|_| 1 + r.below(64) as usize).collect();
                topo
            },
            |topo| {
                let cfg = NpuConfig::default();
                let c = mlp_cost(&cfg, topo);
                let macs: u64 =
                    topo.windows(2).map(|w| (w[0] * w[1]) as u64).sum();
                if c.macs != macs {
                    return Err(format!("macs {} != {macs}", c.macs));
                }
                for l in &c.layers {
                    if l.cycles < l.compute_cycles.max(l.io_cycles) {
                        return Err("cycles below max(compute, io)".into());
                    }
                }
                // Widening any hidden layer cannot reduce cycles.
                if topo.len() >= 3 {
                    let mut wider = topo.clone();
                    wider[1] += 8;
                    let cw = mlp_cost(&cfg, &wider);
                    if cw.cycles < c.cycles {
                        return Err("wider net got cheaper".into());
                    }
                }
                Ok(())
            },
        );
    }
}
