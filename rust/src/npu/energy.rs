//! Energy model: per-event pJ constants applied to the cost counts.
//!
//! Constants (`config::NpuConfig`) are order-of-magnitude 45 nm figures in
//! the spirit of [10]'s NPU evaluation: a MAC ~1 pJ, on-chip SRAM access a
//! few pJ/word, an OoO CPU cycle a few hundred pJ.  Fig. 8 reports RATIOS
//! normalised to the one-pass method, so only the relative magnitudes
//! (CPU cycle >> MAC) matter for reproducing the paper's shape.

//! Precision: int8 inference charges `e_mac_q8_pj` per MAC and moves 4
//! packed values per bus word, so quantized nets are cheaper on both the
//! MAC and the data-movement term (the Fig. 8 energy axis under
//! `ExecMode::NativeQ8`).

use crate::config::{NpuConfig, Precision};

use super::cost::MlpCost;

/// Applies the config's energy constants to cost counts.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    cfg: NpuConfig,
}

impl EnergyModel {
    pub fn new(cfg: NpuConfig) -> Self {
        EnergyModel { cfg }
    }

    /// Energy of one MLP inference on the NPU (pJ): MACs + bus traffic,
    /// at the cost's datapath precision.
    pub fn mlp(&self, cost: &MlpCost) -> f64 {
        let e_mac = match cost.precision {
            Precision::F32 => self.cfg.e_mac_pj,
            Precision::Int8 => self.cfg.e_mac_q8_pj,
        };
        let words = (cost.bus_words as f64 / cost.precision.values_per_word() as f64).ceil();
        cost.macs as f64 * e_mac + words * self.cfg.e_bus_word_pj
    }

    /// Energy of refilling `cycles`-worth of weights from cache (pJ).
    pub fn weight_refill(&self, refill_cycles: u64, cfg: &NpuConfig) -> f64 {
        (refill_cycles * cfg.cache_refill_words_per_cycle) as f64 * cfg.e_cache_word_pj
    }

    /// Energy of one precise CPU evaluation (pJ).
    pub fn cpu(&self, cpu_cycles: u64) -> f64 {
        cpu_cycles as f64 * self.cfg.e_cpu_cycle_pj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::npu::cost::mlp_cost;

    #[test]
    fn npu_inference_cheaper_than_cpu_for_paper_nets() {
        let cfg = NpuConfig::default();
        let e = EnergyModel::new(cfg);
        // Largest paper net (jmeint approximator) vs its CPU cost.
        let cost = mlp_cost(&cfg, &[18, 32, 16, 2]);
        assert!(e.mlp(&cost) < e.cpu(800), "NPU {} vs CPU {}", e.mlp(&cost), e.cpu(800));
    }

    #[test]
    fn energy_scales_with_macs() {
        let cfg = NpuConfig::default();
        let e = EnergyModel::new(cfg);
        let small = mlp_cost(&cfg, &[2, 4, 1]);
        let big = mlp_cost(&cfg, &[64, 16, 64]);
        assert!(e.mlp(&big) > e.mlp(&small));
    }

    #[test]
    fn int8_inference_cheaper_than_f32() {
        use crate::config::Precision;
        use crate::npu::cost::mlp_cost_prec;
        let cfg = NpuConfig::default();
        let e = EnergyModel::new(cfg);
        for topo in [vec![6, 8, 1], vec![18, 32, 16, 2]] {
            let f = mlp_cost_prec(&cfg, &topo, Precision::F32);
            let q = mlp_cost_prec(&cfg, &topo, Precision::Int8);
            assert!(
                e.mlp(&q) < e.mlp(&f),
                "{topo:?}: int8 {} !< f32 {}",
                e.mlp(&q),
                e.mlp(&f)
            );
        }
    }
}
