//! Readers AND writers for the binary + JSON artifacts (`weights.bin`
//! MCMW, `test.bin` MCMD, quantized MCQW, `manifest.json`).  Historically
//! the Python build path (`python/compile/formats.py`) was the only
//! producer; the write paths here let the native trainer (`crate::train`)
//! emit the same formats, so either side can build an artifact tree.
//!
//! Byte-level specs live in the Python module docstring and DESIGN.md
//! §Artifact formats; the pytest round-trip tests pin the Python side and
//! the round-trip tests here pin the Rust side.

pub mod dataset;
pub mod manifest;
pub mod weights;

pub use dataset::Dataset;
pub use manifest::{BenchManifest, Manifest, WorkloadKind};
pub use weights::{MethodWeights, QuantizedMlpFile, QuantizedTensor, WeightsFile};

use std::io::Read;

pub(crate) fn read_u32(r: &mut impl Read) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn read_u8(r: &mut impl Read) -> crate::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub(crate) fn read_f32s(r: &mut impl Read, n: usize) -> crate::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub(crate) fn read_i8s(r: &mut impl Read, n: usize) -> crate::Result<Vec<i8>> {
    let mut bytes = vec![0u8; n];
    r.read_exact(&mut bytes)?;
    Ok(bytes.into_iter().map(|b| b as i8).collect())
}

pub(crate) fn read_string(r: &mut impl Read) -> crate::Result<String> {
    let len = read_u32(r)? as usize;
    anyhow::ensure!(len < 1 << 20, "unreasonable string length {len}");
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    Ok(String::from_utf8(bytes)?)
}
