//! `manifest.json` — the contract between the artifact build and this
//! runtime: topologies, normalisation bounds, error bounds, file layout.
//! Historically written only by the Python build; the write path below lets
//! the native trainer (`crate::train`) create or extend an artifact tree
//! with no Python anywhere in the loop.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};

/// Where a workload's ground truth comes from.
///
/// * `Synthetic` — a registered precise [`crate::benchmarks::BenchFn`]
///   exists at runtime: the CPU fallback and QoS shadow verification can
///   re-execute the oracle on demand (the paper's eight benchmarks).
/// * `Table` — the workload was defined purely by a data file
///   (`mcma train --data`): no closed-form oracle exists at runtime, so
///   the precise path must route through a
///   [`crate::workload::PreciseProxy`] (held-out nearest-record lookup or
///   reject-with-error) and shadow verification scores against held-out
///   labels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WorkloadKind {
    #[default]
    Synthetic,
    Table,
}

impl WorkloadKind {
    /// Manifest key (`"kind"` field, v2).
    pub fn key(self) -> &'static str {
        match self {
            WorkloadKind::Synthetic => "synthetic",
            WorkloadKind::Table => "table",
        }
    }

    pub fn from_key(s: &str) -> crate::Result<Self> {
        match s {
            "synthetic" => Ok(WorkloadKind::Synthetic),
            "table" => Ok(WorkloadKind::Table),
            _ => anyhow::bail!("unknown workload kind {s:?} (synthetic|table)"),
        }
    }
}

/// Per-benchmark manifest entry.
#[derive(Clone, Debug)]
pub struct BenchManifest {
    pub name: String,
    pub domain: String,
    /// v2: where ground truth comes from (absent in v1 manifests, which
    /// only ever described the registered paper benchmarks — defaults to
    /// [`WorkloadKind::Synthetic`]).
    pub kind: WorkloadKind,
    /// v2: content digest of the source data file (hex FNV-1a 64) for
    /// `Table` workloads, so a retrain against changed data is detected;
    /// empty for synthetic workloads.
    pub source_digest: String,
    pub n_in: usize,
    pub n_out: usize,
    pub approx_topology: Vec<usize>,
    pub clf2_topology: Vec<usize>,
    pub clfn_topology: Vec<usize>,
    pub x_lo: Vec<f32>,
    pub x_hi: Vec<f32>,
    pub y_lo: Vec<f32>,
    pub y_hi: Vec<f32>,
    pub error_bound: f64,
    pub train_n: usize,
    pub test_n: usize,
    pub methods: Vec<String>,
    pub mcca_pairs: usize,
}

impl BenchManifest {
    /// Normalise one raw input row into NN space (matches
    /// `python/compile/benchmarks.py::Benchmark.normalize_x`).
    pub fn normalize_x_into(&self, raw: &[f32], out: &mut [f32]) {
        debug_assert_eq!(raw.len(), self.n_in);
        for i in 0..self.n_in {
            out[i] = (raw[i] - self.x_lo[i]) / (self.x_hi[i] - self.x_lo[i]);
        }
    }

    pub fn normalize_y_into(&self, raw: &[f64], out: &mut [f32]) {
        debug_assert_eq!(raw.len(), self.n_out);
        for i in 0..self.n_out {
            out[i] = ((raw[i] - self.y_lo[i] as f64) / (self.y_hi[i] - self.y_lo[i]) as f64) as f32;
        }
    }

    /// Serialise to the JSON object shape `parse_bench` reads back.
    pub fn to_json(&self) -> Value {
        let usizes = |xs: &[usize]| {
            Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
        };
        let f32s = |xs: &[f32]| {
            Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
        };
        json::obj(vec![
            ("domain", Value::Str(self.domain.clone())),
            ("kind", Value::Str(self.kind.key().to_string())),
            ("source_digest", Value::Str(self.source_digest.clone())),
            ("n_in", Value::Num(self.n_in as f64)),
            ("n_out", Value::Num(self.n_out as f64)),
            ("approx_topology", usizes(&self.approx_topology)),
            ("clf2_topology", usizes(&self.clf2_topology)),
            ("clfN_topology", usizes(&self.clfn_topology)),
            ("x_lo", f32s(&self.x_lo)),
            ("x_hi", f32s(&self.x_hi)),
            ("y_lo", f32s(&self.y_lo)),
            ("y_hi", f32s(&self.y_hi)),
            ("error_bound", Value::Num(self.error_bound)),
            ("train_n", Value::Num(self.train_n as f64)),
            ("test_n", Value::Num(self.test_n as f64)),
            (
                "methods",
                Value::Arr(self.methods.iter().map(|m| Value::Str(m.clone())).collect()),
            ),
            ("mcca_pairs", Value::Num(self.mcca_pairs as f64)),
        ])
    }
}

/// The whole artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub n_approx: usize,
    pub batch_sizes: Vec<usize>,
    pub benchmarks: HashMap<String, BenchManifest>,
    pub root: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_root: &Path) -> crate::Result<Self> {
        let v = json::parse_file(&artifacts_root.join("manifest.json"))?;
        let n_approx = v.req("n_approx")?.as_usize().unwrap_or(3);
        let batch_sizes = v
            .req("batch_sizes")?
            .as_usize_vec()
            .ok_or_else(|| anyhow::anyhow!("bad batch_sizes"))?;
        let mut benchmarks = HashMap::new();
        for (name, b) in v.req("benchmarks")?.as_obj().unwrap_or(&[]) {
            benchmarks.insert(name.clone(), parse_bench(name, b)?);
        }
        anyhow::ensure!(!benchmarks.is_empty(), "manifest lists no benchmarks");
        Ok(Manifest { n_approx, batch_sizes, benchmarks, root: artifacts_root.to_path_buf() })
    }

    pub fn bench(&self, name: &str) -> crate::Result<&BenchManifest> {
        self.benchmarks
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("benchmark {name:?} not in manifest"))
    }

    /// Benchmarks in the paper's Fig. 6 order (unknown names sort last).
    pub fn bench_names_ordered(&self) -> Vec<String> {
        const ORDER: [&str; 8] = [
            "blackscholes", "fft", "inversek2j", "jmeint",
            "jpeg", "kmeans", "sobel", "bessel",
        ];
        let mut names: Vec<String> = self.benchmarks.keys().cloned().collect();
        names.sort_by_key(|n| ORDER.iter().position(|o| o == n).unwrap_or(ORDER.len()));
        names
    }

    /// Path helpers.
    pub fn weights_path(&self, bench: &str) -> PathBuf {
        self.root.join(bench).join("weights.bin")
    }

    pub fn dataset_path(&self, bench: &str) -> PathBuf {
        self.root.join(bench).join("test.bin")
    }

    pub fn hlo_path(&self, bench: &str, role: &str, batch: usize) -> PathBuf {
        self.root.join(bench).join(format!("{role}_b{batch}.hlo.txt"))
    }

    /// Rust-trained weights variant written by `mcma train` alongside the
    /// Python-trained `weights.bin` (consumed by the `mcma summary`
    /// Python-vs-Rust comparison).
    pub fn rust_weights_path(&self, bench: &str) -> PathBuf {
        self.root.join(bench).join("weights_rust.bin")
    }

    /// Serialise to the JSON document shape `load` reads back.  Benchmarks
    /// are emitted in the Fig. 6 display order so output is deterministic.
    pub fn to_json(&self) -> Value {
        let benches: Vec<(String, Value)> = self
            .bench_names_ordered()
            .into_iter()
            .map(|name| {
                let b = self.benchmarks[&name].to_json();
                (name, b)
            })
            .collect();
        Value::Obj(vec![
            // v2 adds per-benchmark `kind` + `source_digest`; both are
            // optional on read, so v1 trees keep loading unchanged.
            ("version".to_string(), Value::Num(2.0)),
            ("n_approx".to_string(), Value::Num(self.n_approx as f64)),
            (
                "batch_sizes".to_string(),
                Value::Arr(self.batch_sizes.iter().map(|&b| Value::Num(b as f64)).collect()),
            ),
            ("benchmarks".to_string(), Value::Obj(benches)),
        ])
    }

    /// Write `manifest.json` under `dir` (usually the artifact root).
    pub fn save_to(&self, dir: &Path) -> crate::Result<()> {
        let path = dir.join("manifest.json");
        std::fs::write(&path, json::write(&self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Insert or replace one benchmark entry (the trainer's export path:
    /// load-or-create a manifest, upsert, save).
    pub fn upsert_bench(&mut self, bench: BenchManifest) {
        self.benchmarks.insert(bench.name.clone(), bench);
    }
}

fn parse_bench(name: &str, v: &Value) -> crate::Result<BenchManifest> {
    let topo = |key: &str| -> crate::Result<Vec<usize>> {
        v.req(key)?
            .as_usize_vec()
            .ok_or_else(|| anyhow::anyhow!("bad {key} for {name}"))
    };
    let f32s = |key: &str| -> crate::Result<Vec<f32>> {
        v.req(key)?
            .as_f32_vec()
            .ok_or_else(|| anyhow::anyhow!("bad {key} for {name}"))
    };
    let m = BenchManifest {
        name: name.to_string(),
        domain: v.req("domain")?.as_str().unwrap_or("").to_string(),
        kind: match v.get("kind").and_then(Value::as_str) {
            Some(k) => WorkloadKind::from_key(k)
                .map_err(|e| anyhow::anyhow!("{name}: {e}"))?,
            None => WorkloadKind::Synthetic, // v1 manifests
        },
        source_digest: v
            .get("source_digest")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        n_in: v.req("n_in")?.as_usize().unwrap_or(0),
        n_out: v.req("n_out")?.as_usize().unwrap_or(0),
        approx_topology: topo("approx_topology")?,
        clf2_topology: topo("clf2_topology")?,
        clfn_topology: topo("clfN_topology")?,
        x_lo: f32s("x_lo")?,
        x_hi: f32s("x_hi")?,
        y_lo: f32s("y_lo")?,
        y_hi: f32s("y_hi")?,
        error_bound: v.req("error_bound")?.as_f64().unwrap_or(0.0),
        train_n: v.req("train_n")?.as_usize().unwrap_or(0),
        test_n: v.req("test_n")?.as_usize().unwrap_or(0),
        methods: v
            .req("methods")?
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default(),
        mcca_pairs: v.get("mcca_pairs").and_then(Value::as_usize).unwrap_or(0),
    };
    anyhow::ensure!(m.n_in == m.approx_topology[0], "{name}: n_in/topology mismatch");
    anyhow::ensure!(
        m.n_out == *m.approx_topology.last().unwrap(),
        "{name}: n_out/topology mismatch"
    );
    anyhow::ensure!(m.x_lo.len() == m.n_in && m.x_hi.len() == m.n_in, "{name}: x bounds");
    anyhow::ensure!(m.y_lo.len() == m.n_out && m.y_hi.len() == m.n_out, "{name}: y bounds");
    anyhow::ensure!(m.error_bound > 0.0, "{name}: error bound must be positive");
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "n_approx": 3, "batch_sizes": [1, 256],
      "train_config": {},
      "benchmarks": {
        "sobel": {
          "domain": "Image Processing", "n_in": 9, "n_out": 1,
          "approx_topology": [9, 8, 1], "clf2_topology": [9, 8, 2],
          "clfN_topology": [9, 8, 4],
          "x_lo": [0,0,0,0,0,0,0,0,0], "x_hi": [1,1,1,1,1,1,1,1,1],
          "y_lo": [0], "y_hi": [1], "error_bound": 0.035,
          "train_n": 100, "test_n": 50,
          "methods": ["one_pass"], "mcca_pairs": 2
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("mcma_mantest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.n_approx, 3);
        let b = m.bench("sobel").unwrap();
        assert_eq!(b.clfn_topology, vec![9, 8, 4]);
        assert_eq!(b.mcca_pairs, 2);
        assert!(m.bench("nope").is_err());
        assert!(m.hlo_path("sobel", "approx", 256).ends_with("sobel/approx_b256.hlo.txt"));
    }

    /// The manifest write path round-trips: every field `load` validates
    /// survives save -> load.
    #[test]
    fn write_path_roundtrips() {
        let dir = std::env::temp_dir().join(format!("mcma_mantest_w_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let mut m = Manifest::load(&dir).unwrap();

        // Upsert a second benchmark the way the trainer does.
        let extra = BenchManifest {
            name: "bessel".into(),
            domain: "Scientific".into(),
            kind: WorkloadKind::Table,
            source_digest: "deadbeefcafef00d".into(),
            n_in: 2,
            n_out: 1,
            approx_topology: vec![2, 8, 8, 1],
            clf2_topology: vec![2, 8, 2],
            clfn_topology: vec![2, 16, 5],
            x_lo: vec![0.0, 0.5],
            x_hi: vec![4.0, 20.0],
            y_lo: vec![-0.5],
            y_hi: vec![1.0],
            error_bound: 0.025,
            train_n: 4000,
            test_n: 1000,
            methods: vec!["one_pass".into(), "mcma_competitive".into()],
            mcca_pairs: 0,
        };
        m.upsert_bench(extra.clone());
        m.save_to(&dir).unwrap();

        let back = Manifest::load(&dir).unwrap();
        assert_eq!(back.n_approx, m.n_approx);
        assert_eq!(back.batch_sizes, m.batch_sizes);
        assert_eq!(back.benchmarks.len(), 2);
        let b = back.bench("bessel").unwrap();
        assert_eq!(b.approx_topology, extra.approx_topology);
        assert_eq!(b.clfn_topology, extra.clfn_topology);
        assert_eq!(b.x_hi, extra.x_hi);
        assert!((b.error_bound - extra.error_bound).abs() < 1e-12);
        assert_eq!(b.methods, extra.methods);
        // v2 fields round-trip (kind + source digest).
        assert_eq!(b.kind, WorkloadKind::Table);
        assert_eq!(b.source_digest, "deadbeefcafef00d");
        // The original v1-parsed entry survives the rewrite and defaults
        // to the synthetic kind.
        assert_eq!(back.bench("sobel").unwrap().clfn_topology, vec![9, 8, 4]);
        assert_eq!(back.bench("sobel").unwrap().kind, WorkloadKind::Synthetic);
        assert_eq!(back.bench("sobel").unwrap().source_digest, "");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn normalize_x_matches_formula() {
        let b = BenchManifest {
            name: "t".into(), domain: String::new(),
            kind: WorkloadKind::Synthetic, source_digest: String::new(),
            n_in: 2, n_out: 1,
            approx_topology: vec![2, 1], clf2_topology: vec![2, 2],
            clfn_topology: vec![2, 4],
            x_lo: vec![0.0, -1.0], x_hi: vec![2.0, 1.0],
            y_lo: vec![0.0], y_hi: vec![10.0],
            error_bound: 0.1, train_n: 0, test_n: 0,
            methods: vec![], mcca_pairs: 0,
        };
        let mut out = [0.0f32; 2];
        b.normalize_x_into(&[1.0, 0.0], &mut out);
        assert_eq!(out, [0.5, 0.5]);
        let mut y = [0.0f32; 1];
        b.normalize_y_into(&[5.0], &mut y);
        assert_eq!(y[0], 0.5);
    }
}
