//! `weights.bin` reader AND writer (magic `MCMW`, v1) — trained nets for
//! every method — plus the per-tensor symmetric int8 quantizer and the
//! quantized weight format (magic `MCQW`, v1) consumed by the `nn::qgemm`
//! engine.  The write path serialises the exact byte layout the reader
//! parses (and `python/compile/formats.py` emits), so the native trainer
//! (`crate::train`) exports artifacts `ModelBank` loads unchanged.

use std::collections::HashMap;
use std::io::{BufReader, Read};
use std::path::Path;

use crate::nn::{Layer, Matrix, Mlp};

use super::{read_f32s, read_i8s, read_string, read_u32, read_u8};

/// One training method's nets: classifier(s) + approximator(s).
#[derive(Clone, Debug, PartialEq)]
pub struct MethodWeights {
    pub method: String,
    /// MCCA stores one binary classifier per cascade pair.
    pub cascade: bool,
    /// 2 for binary, n+1 for the MCMA multiclass classifier.
    pub clf_classes: usize,
    pub classifiers: Vec<Mlp>,
    pub approximators: Vec<Mlp>,
}

impl MethodWeights {
    /// The single classifier of non-cascade methods.
    pub fn classifier(&self) -> &Mlp {
        assert!(!self.cascade, "cascade methods have per-pair classifiers");
        &self.classifiers[0]
    }
}

/// Parsed `weights.bin`: method name -> nets.
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsFile {
    pub methods: HashMap<String, MethodWeights>,
}

impl WeightsFile {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"MCMW", "bad weights magic {magic:?}");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == 1, "unsupported weights version {version}");
        let n_methods = read_u32(&mut r)? as usize;
        anyhow::ensure!(n_methods <= 64, "unreasonable method count {n_methods}");
        let mut methods = HashMap::new();
        for _ in 0..n_methods {
            let method = read_string(&mut r)?;
            let cascade = read_u8(&mut r)? != 0;
            let clf_classes = read_u32(&mut r)? as usize;
            let n_clf = read_u32(&mut r)? as usize;
            let classifiers = (0..n_clf)
                .map(|_| read_mlp(&mut r))
                .collect::<crate::Result<Vec<_>>>()?;
            let n_approx = read_u32(&mut r)? as usize;
            let approximators = (0..n_approx)
                .map(|_| read_mlp(&mut r))
                .collect::<crate::Result<Vec<_>>>()?;
            methods.insert(
                method.clone(),
                MethodWeights { method, cascade, clf_classes, classifiers, approximators },
            );
        }
        Ok(WeightsFile { methods })
    }

    pub fn get(&self, method: &str) -> crate::Result<&MethodWeights> {
        self.methods
            .get(method)
            .ok_or_else(|| anyhow::anyhow!("method {method:?} not in weights file"))
    }

    /// Serialise to the MCMW v1 byte layout `load` parses.  Methods are
    /// written in sorted name order so the output is deterministic.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCMW");
        buf.extend(1u32.to_le_bytes());
        buf.extend((self.methods.len() as u32).to_le_bytes());
        let mut names: Vec<&String> = self.methods.keys().collect();
        names.sort();
        for name in names {
            let mw = &self.methods[name];
            buf.extend((name.len() as u32).to_le_bytes());
            buf.extend(name.as_bytes());
            buf.push(mw.cascade as u8);
            buf.extend((mw.clf_classes as u32).to_le_bytes());
            buf.extend((mw.classifiers.len() as u32).to_le_bytes());
            for m in &mw.classifiers {
                write_mlp(&mut buf, m);
            }
            buf.extend((mw.approximators.len() as u32).to_le_bytes());
            for m in &mw.approximators {
                write_mlp(&mut buf, m);
            }
        }
        buf
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

fn write_mlp(buf: &mut Vec<u8>, mlp: &Mlp) {
    buf.extend((mlp.layers.len() as u32).to_le_bytes());
    for l in &mlp.layers {
        buf.extend((l.w.rows as u32).to_le_bytes());
        buf.extend((l.w.cols as u32).to_le_bytes());
        for v in &l.w.data {
            buf.extend(v.to_le_bytes());
        }
        buf.extend((l.b.len() as u32).to_le_bytes());
        for v in &l.b {
            buf.extend(v.to_le_bytes());
        }
    }
}

/// Per-tensor symmetric int8 quantization: zero-point 0, scale chosen so
/// the largest magnitude maps to ±127 (the -128 code is never produced —
/// the range stays symmetric, matching fixed-point MAC arrays).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub data: Vec<i8>,
    /// Dequantization scale: `value ≈ data[i] as f32 * scale`.
    pub scale: f32,
}

impl QuantizedTensor {
    /// Symmetric scale for `values`: largest magnitude maps to ±127
    /// (1.0 for an all-zero tensor, avoiding a zero divide).
    pub fn scale_for(values: &[f32]) -> f32 {
        let amax = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax > 0.0 {
            amax / 127.0
        } else {
            1.0
        }
    }

    /// Quantize `values` into `out` at `scale`.  The ONE rounding routine
    /// every quantization site shares (weights at pack time, activation
    /// panels in `nn::qgemm`), so identical f32 inputs always produce
    /// identical codes.  Multiplies by the reciprocal — ~4x cheaper than
    /// dividing per element on the activation hot path, and the <= 1 ulp
    /// difference vs exact division stays inside the half-step error
    /// bound the engines are property-tested against.
    pub fn quantize_into(values: &[f32], scale: f32, out: &mut [i8]) {
        let inv = 1.0 / scale;
        for (q, &v) in out.iter_mut().zip(values) {
            *q = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }

    pub fn quantize(values: &[f32]) -> Self {
        let scale = Self::scale_for(values);
        let mut data = vec![0i8; values.len()];
        Self::quantize_into(values, scale, &mut data);
        QuantizedTensor { data, scale }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        self.data.iter().map(|&q| q as f32 * self.scale).collect()
    }

    /// Worst-case absolute reconstruction error: half a quantization step.
    pub fn max_abs_err(&self) -> f32 {
        0.5 * self.scale
    }
}

/// One quantized dense layer as stored on disk: int8 weights + f32 bias
/// (bias stays full precision — it adds into the i32 accumulator's f32
/// requantization, exactly as the qgemm engine computes).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedLayerRecord {
    pub rows: usize,
    pub cols: usize,
    pub w: QuantizedTensor,
    pub b: Vec<f32>,
}

/// A whole quantized MLP (magic `MCQW`, v1).  Layout per layer:
/// `u32 rows, u32 cols, f32 scale, rows*cols i8, u32 blen, blen f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedMlpFile {
    pub layers: Vec<QuantizedLayerRecord>,
}

impl QuantizedMlpFile {
    pub fn from_mlp(mlp: &Mlp) -> Self {
        let layers = mlp
            .layers
            .iter()
            .map(|l| QuantizedLayerRecord {
                rows: l.w.rows,
                cols: l.w.cols,
                w: QuantizedTensor::quantize(&l.w.data),
                b: l.b.clone(),
            })
            .collect();
        QuantizedMlpFile { layers }
    }

    /// Dequantized f32 twin: every weight within half a step of the
    /// original (`QuantizedTensor::max_abs_err`).
    pub fn to_mlp(&self) -> Mlp {
        let layers = self
            .layers
            .iter()
            .map(|l| Layer {
                w: Matrix::new(l.rows, l.cols, l.w.dequantize()),
                b: l.b.clone(),
            })
            .collect();
        Mlp::new(layers)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCQW");
        buf.extend(1u32.to_le_bytes());
        buf.extend((self.layers.len() as u32).to_le_bytes());
        for l in &self.layers {
            buf.extend((l.rows as u32).to_le_bytes());
            buf.extend((l.cols as u32).to_le_bytes());
            buf.extend(l.w.scale.to_le_bytes());
            buf.extend(l.w.data.iter().map(|&q| q as u8));
            buf.extend((l.b.len() as u32).to_le_bytes());
            for v in &l.b {
                buf.extend(v.to_le_bytes());
            }
        }
        buf
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        Self::read(&mut BufReader::new(f))
    }

    pub fn read(r: &mut impl Read) -> crate::Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"MCQW", "bad quantized weights magic {magic:?}");
        let version = read_u32(r)?;
        anyhow::ensure!(version == 1, "unsupported quantized weights version {version}");
        let n_layers = read_u32(r)? as usize;
        anyhow::ensure!(
            (1..=16).contains(&n_layers),
            "unreasonable layer count {n_layers}"
        );
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let rows = read_u32(r)? as usize;
            let cols = read_u32(r)? as usize;
            anyhow::ensure!(rows * cols <= 1 << 24, "unreasonable layer size");
            let scale = read_f32s(r, 1)?[0];
            anyhow::ensure!(
                scale.is_finite() && scale > 0.0,
                "bad quantization scale {scale}"
            );
            let data = read_i8s(r, rows * cols)?;
            let blen = read_u32(r)? as usize;
            anyhow::ensure!(blen == cols, "bias length {blen} != cols {cols}");
            let b = read_f32s(r, blen)?;
            layers.push(QuantizedLayerRecord {
                rows,
                cols,
                w: QuantizedTensor { data, scale },
                b,
            });
        }
        Ok(QuantizedMlpFile { layers })
    }
}

fn read_mlp(r: &mut impl Read) -> crate::Result<Mlp> {
    let n_layers = read_u32(r)? as usize;
    anyhow::ensure!(
        (1..=16).contains(&n_layers),
        "unreasonable layer count {n_layers}"
    );
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        anyhow::ensure!(rows * cols <= 1 << 24, "unreasonable layer size");
        let w = read_f32s(r, rows * cols)?;
        let blen = read_u32(r)? as usize;
        anyhow::ensure!(blen == cols, "bias length {blen} != cols {cols}");
        let b = read_f32s(r, blen)?;
        layers.push(Layer { w: Matrix::new(rows, cols, w), b });
    }
    Ok(Mlp::new(layers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-build a v1 weights file with one method and check the parse.
    #[test]
    fn parses_handbuilt_file() {
        let dir = std::env::temp_dir().join("mcma_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCMW");
        buf.extend(1u32.to_le_bytes()); // version
        buf.extend(1u32.to_le_bytes()); // n_methods
        buf.extend(8u32.to_le_bytes()); // name len
        buf.extend(b"one_pass");
        buf.push(0); // cascade = false
        buf.extend(2u32.to_le_bytes()); // clf_classes
        buf.extend(1u32.to_le_bytes()); // n_classifiers
        // classifier mlp: 1 layer 2x2
        buf.extend(1u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            buf.extend(v.to_le_bytes());
        }
        buf.extend(2u32.to_le_bytes());
        for v in [0.5f32, -0.5] {
            buf.extend(v.to_le_bytes());
        }
        // 1 approximator: 1 layer 2x1
        buf.extend(1u32.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        for v in [7.0f32, 8.0] {
            buf.extend(v.to_le_bytes());
        }
        buf.extend(1u32.to_le_bytes());
        buf.extend(9.0f32.to_le_bytes());
        std::fs::File::create(&path).unwrap().write_all(&buf).unwrap();

        let wf = WeightsFile::load(&path).unwrap();
        let m = wf.get("one_pass").unwrap();
        assert!(!m.cascade);
        assert_eq!(m.clf_classes, 2);
        assert_eq!(m.classifier().topology(), vec![2, 2]);
        assert_eq!(m.approximators[0].layers[0].w.at(0, 0), 7.0);
        assert_eq!(m.approximators[0].layers[0].b[0], 9.0);
        assert!(wf.get("nope").is_err());
    }

    /// The MCMW write path round-trips through the reader: nets, cascade
    /// flags and class counts all survive save -> load bit-for-bit (f32
    /// little-endian both ways), including a multi-method file.
    #[test]
    fn weights_write_path_roundtrips() {
        use crate::util::{prop, rng::Rng};
        let dir = std::env::temp_dir().join("mcma_wtest_write");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("w_{}.bin", std::process::id()));

        let mut r = Rng::new(0x77E1);
        let mut methods = HashMap::new();
        methods.insert(
            "one_pass".to_string(),
            MethodWeights {
                method: "one_pass".into(),
                cascade: false,
                clf_classes: 2,
                classifiers: vec![prop::gens::mlp(&mut r, &[6, 8, 2], 1.5, 0.5)],
                approximators: vec![prop::gens::mlp(&mut r, &[6, 8, 1], 1.5, 0.5)],
            },
        );
        methods.insert(
            "mcma_competitive".to_string(),
            MethodWeights {
                method: "mcma_competitive".into(),
                cascade: false,
                clf_classes: 4,
                classifiers: vec![prop::gens::mlp(&mut r, &[6, 8, 4], 1.5, 0.5)],
                approximators: (0..3)
                    .map(|_| prop::gens::mlp(&mut r, &[6, 8, 1], 1.5, 0.5))
                    .collect(),
            },
        );
        let wf = WeightsFile { methods };
        wf.save(&path).unwrap();
        let back = WeightsFile::load(&path).unwrap();
        // `method` field is reconstructed from the file key on load.
        assert_eq!(back.methods.len(), 2);
        for (name, mw) in &wf.methods {
            let b = back.get(name).unwrap();
            assert_eq!(b.cascade, mw.cascade);
            assert_eq!(b.clf_classes, mw.clf_classes);
            assert_eq!(b.classifiers, mw.classifiers, "{name} classifiers");
            assert_eq!(b.approximators, mw.approximators, "{name} approximators");
        }
        // Deterministic bytes: two serialisations are identical.
        assert_eq!(wf.to_bytes(), back.to_bytes());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("mcma_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(WeightsFile::load(&path).is_err());
    }

    #[test]
    fn quantizer_hand_checked() {
        let q = QuantizedTensor::quantize(&[1.0, -0.5, 0.25, -1.0]);
        // amax = 1.0, scale = 1/127: ±1.0 -> ±127, -0.5 -> -64 (rounded).
        assert_eq!(q.data, vec![127, -64, 32, -127]);
        assert!((q.scale - 1.0 / 127.0).abs() < 1e-9);
        let d = q.dequantize();
        for (orig, deq) in [1.0f32, -0.5, 0.25, -1.0].iter().zip(&d) {
            assert!((orig - deq).abs() <= q.max_abs_err(), "{orig} vs {deq}");
        }
        // All-zero tensors quantize without dividing by zero.
        let z = QuantizedTensor::quantize(&[0.0; 4]);
        assert_eq!(z.data, vec![0; 4]);
        assert_eq!(z.dequantize(), vec![0.0; 4]);
    }

    /// Property: dequantization reconstructs every element within half a
    /// quantization step, and the extreme element maps to ±127 exactly.
    #[test]
    fn prop_quantize_error_bounded() {
        use crate::util::{prop, rng::Rng};
        prop::check(
            "quantize-error-bound",
            200,
            0x0708,
            |r: &mut Rng| prop::gens::vec_f32(r, 1 + r.below(256) as usize, -8.0, 8.0),
            |values| {
                let q = QuantizedTensor::quantize(values);
                let deq = q.dequantize();
                for (i, (&v, &d)) in values.iter().zip(&deq).enumerate() {
                    if (v - d).abs() > q.max_abs_err() + 1e-6 {
                        return Err(format!("element {i}: {v} vs {d} (step {})", q.scale));
                    }
                }
                let amax = values.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                if amax > 0.0 && !q.data.iter().any(|&c| c.abs() == 127) {
                    return Err("extreme element did not map to ±127".into());
                }
                Ok(())
            },
        );
    }

    /// The quantized weight format round-trips bitwise: codes, scales and
    /// biases all survive save -> load.
    #[test]
    fn quantized_format_roundtrip() {
        use crate::util::{prop, rng::Rng};
        let dir = std::env::temp_dir().join("mcma_qwtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("q_{}.bin", std::process::id()));

        let mut r = Rng::new(0x0F0F);
        let mlp = prop::gens::mlp(&mut r, &[6, 8, 8, 1], 2.0, 1.0);
        let qf = QuantizedMlpFile::from_mlp(&mlp);
        qf.save(&path).unwrap();
        let back = QuantizedMlpFile::load(&path).unwrap();
        assert_eq!(qf, back, "quantized weights did not round-trip bitwise");

        // The dequantized twin stays within half a step per weight.
        let twin = back.to_mlp();
        assert_eq!(twin.topology(), mlp.topology());
        for (lq, (lt, lo)) in back.layers.iter().zip(twin.layers.iter().zip(&mlp.layers)) {
            assert_eq!(lt.b, lo.b, "bias must be exact (stored f32)");
            for (&t, &o) in lt.w.data.iter().zip(&lo.w.data) {
                assert!((t - o).abs() <= lq.w.max_abs_err() + 1e-6);
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn quantized_format_rejects_corruption() {
        let mut r = crate::util::rng::Rng::new(7);
        let mlp = crate::util::prop::gens::mlp(&mut r, &[3, 4, 1], 1.0, 0.5);
        let mut bytes = QuantizedMlpFile::from_mlp(&mlp).to_bytes();
        bytes[0] = b'X'; // bad magic
        assert!(QuantizedMlpFile::read(&mut bytes.as_slice()).is_err());
        let good = QuantizedMlpFile::from_mlp(&mlp).to_bytes();
        assert!(QuantizedMlpFile::read(&mut &good[..good.len() - 2]).is_err());
    }
}
