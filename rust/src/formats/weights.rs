//! `weights.bin` reader (magic `MCMW`, v1) — trained nets for every method.

use std::collections::HashMap;
use std::io::{BufReader, Read};
use std::path::Path;

use crate::nn::{Layer, Matrix, Mlp};

use super::{read_f32s, read_string, read_u32, read_u8};

/// One training method's nets: classifier(s) + approximator(s).
#[derive(Clone, Debug)]
pub struct MethodWeights {
    pub method: String,
    /// MCCA stores one binary classifier per cascade pair.
    pub cascade: bool,
    /// 2 for binary, n+1 for the MCMA multiclass classifier.
    pub clf_classes: usize,
    pub classifiers: Vec<Mlp>,
    pub approximators: Vec<Mlp>,
}

impl MethodWeights {
    /// The single classifier of non-cascade methods.
    pub fn classifier(&self) -> &Mlp {
        assert!(!self.cascade, "cascade methods have per-pair classifiers");
        &self.classifiers[0]
    }
}

/// Parsed `weights.bin`: method name -> nets.
#[derive(Clone, Debug)]
pub struct WeightsFile {
    pub methods: HashMap<String, MethodWeights>,
}

impl WeightsFile {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"MCMW", "bad weights magic {magic:?}");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == 1, "unsupported weights version {version}");
        let n_methods = read_u32(&mut r)? as usize;
        anyhow::ensure!(n_methods <= 64, "unreasonable method count {n_methods}");
        let mut methods = HashMap::new();
        for _ in 0..n_methods {
            let method = read_string(&mut r)?;
            let cascade = read_u8(&mut r)? != 0;
            let clf_classes = read_u32(&mut r)? as usize;
            let n_clf = read_u32(&mut r)? as usize;
            let classifiers = (0..n_clf)
                .map(|_| read_mlp(&mut r))
                .collect::<crate::Result<Vec<_>>>()?;
            let n_approx = read_u32(&mut r)? as usize;
            let approximators = (0..n_approx)
                .map(|_| read_mlp(&mut r))
                .collect::<crate::Result<Vec<_>>>()?;
            methods.insert(
                method.clone(),
                MethodWeights { method, cascade, clf_classes, classifiers, approximators },
            );
        }
        Ok(WeightsFile { methods })
    }

    pub fn get(&self, method: &str) -> crate::Result<&MethodWeights> {
        self.methods
            .get(method)
            .ok_or_else(|| anyhow::anyhow!("method {method:?} not in weights file"))
    }
}

fn read_mlp(r: &mut impl Read) -> crate::Result<Mlp> {
    let n_layers = read_u32(r)? as usize;
    anyhow::ensure!(
        (1..=16).contains(&n_layers),
        "unreasonable layer count {n_layers}"
    );
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rows = read_u32(r)? as usize;
        let cols = read_u32(r)? as usize;
        anyhow::ensure!(rows * cols <= 1 << 24, "unreasonable layer size");
        let w = read_f32s(r, rows * cols)?;
        let blen = read_u32(r)? as usize;
        anyhow::ensure!(blen == cols, "bias length {blen} != cols {cols}");
        let b = read_f32s(r, blen)?;
        layers.push(Layer { w: Matrix::new(rows, cols, w), b });
    }
    Ok(Mlp::new(layers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-build a v1 weights file with one method and check the parse.
    #[test]
    fn parses_handbuilt_file() {
        let dir = std::env::temp_dir().join("mcma_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCMW");
        buf.extend(1u32.to_le_bytes()); // version
        buf.extend(1u32.to_le_bytes()); // n_methods
        buf.extend(8u32.to_le_bytes()); // name len
        buf.extend(b"one_pass");
        buf.push(0); // cascade = false
        buf.extend(2u32.to_le_bytes()); // clf_classes
        buf.extend(1u32.to_le_bytes()); // n_classifiers
        // classifier mlp: 1 layer 2x2
        buf.extend(1u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            buf.extend(v.to_le_bytes());
        }
        buf.extend(2u32.to_le_bytes());
        for v in [0.5f32, -0.5] {
            buf.extend(v.to_le_bytes());
        }
        // 1 approximator: 1 layer 2x1
        buf.extend(1u32.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        for v in [7.0f32, 8.0] {
            buf.extend(v.to_le_bytes());
        }
        buf.extend(1u32.to_le_bytes());
        buf.extend(9.0f32.to_le_bytes());
        std::fs::File::create(&path).unwrap().write_all(&buf).unwrap();

        let wf = WeightsFile::load(&path).unwrap();
        let m = wf.get("one_pass").unwrap();
        assert!(!m.cascade);
        assert_eq!(m.clf_classes, 2);
        assert_eq!(m.classifier().topology(), vec![2, 2]);
        assert_eq!(m.approximators[0].layers[0].w.at(0, 0), 7.0);
        assert_eq!(m.approximators[0].layers[0].b[0], 9.0);
        assert!(wf.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("mcma_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE\x01\x00\x00\x00").unwrap();
        assert!(WeightsFile::load(&path).is_err());
    }
}
