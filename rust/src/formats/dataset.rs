//! `dataset.bin` reader + writer (magic `MCMD`, v1) — held-out test
//! workloads.  The write path lets the native trainer (`crate::train`)
//! export a `test.bin` the eval drivers load unchanged.

use std::io::{BufReader, Read};
use std::path::Path;

use super::{read_f32s, read_u32};

/// A test dataset: raw (un-normalised) inputs plus normalised precise
/// outputs.  The runtime normalises inputs itself using the manifest's
/// static bounds; the raw inputs also feed the precise-CPU fallback path.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    pub n: usize,
    pub d_in: usize,
    pub d_out: usize,
    /// Row-major `(n, d_in)` raw inputs.
    pub x_raw: Vec<f32>,
    /// Row-major `(n, d_out)` normalised precise outputs.
    pub y_norm: Vec<f32>,
}

impl Dataset {
    pub fn load(path: &Path) -> crate::Result<Self> {
        let f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"MCMD", "bad dataset magic {magic:?}");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == 1, "unsupported dataset version {version}");
        let n = read_u32(&mut r)? as usize;
        let d_in = read_u32(&mut r)? as usize;
        let d_out = read_u32(&mut r)? as usize;
        anyhow::ensure!(n * d_in <= 1 << 28, "unreasonable dataset size");
        let x_raw = read_f32s(&mut r, n * d_in)?;
        let y_norm = read_f32s(&mut r, n * d_out)?;
        // Must be exactly at EOF.
        let mut probe = [0u8; 1];
        anyhow::ensure!(
            r.read(&mut probe)? == 0,
            "trailing bytes after dataset payload"
        );
        Ok(Dataset { n, d_in, d_out, x_raw, y_norm })
    }

    /// Raw input row `i`.
    pub fn x_row(&self, i: usize) -> &[f32] {
        &self.x_raw[i * self.d_in..(i + 1) * self.d_in]
    }

    /// Normalised precise output row `i`.
    pub fn y_row(&self, i: usize) -> &[f32] {
        &self.y_norm[i * self.d_out..(i + 1) * self.d_out]
    }

    /// Serialise to the MCMD v1 byte layout `load` parses.
    pub fn to_bytes(&self) -> Vec<u8> {
        assert_eq!(self.x_raw.len(), self.n * self.d_in, "x_raw size mismatch");
        assert_eq!(self.y_norm.len(), self.n * self.d_out, "y_norm size mismatch");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCMD");
        buf.extend(1u32.to_le_bytes());
        buf.extend((self.n as u32).to_le_bytes());
        buf.extend((self.d_in as u32).to_le_bytes());
        buf.extend((self.d_out as u32).to_le_bytes());
        for v in &self.x_raw {
            buf.extend(v.to_le_bytes());
        }
        for v in &self.y_norm {
            buf.extend(v.to_le_bytes());
        }
        buf
    }

    pub fn save(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// A view restricted to the first `n` samples (for quick runs).
    pub fn truncated(&self, n: usize) -> Dataset {
        let n = n.min(self.n);
        Dataset {
            n,
            d_in: self.d_in,
            d_out: self.d_out,
            x_raw: self.x_raw[..n * self.d_in].to_vec(),
            y_norm: self.y_norm[..n * self.d_out].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_ds(path: &Path, n: u32, d_in: u32, d_out: u32, extra: &[u8]) {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(b"MCMD");
        buf.extend(1u32.to_le_bytes());
        buf.extend(n.to_le_bytes());
        buf.extend(d_in.to_le_bytes());
        buf.extend(d_out.to_le_bytes());
        for i in 0..(n * d_in) {
            buf.extend((i as f32).to_le_bytes());
        }
        for i in 0..(n * d_out) {
            buf.extend((100.0 + i as f32).to_le_bytes());
        }
        buf.extend(extra);
        std::fs::File::create(path).unwrap().write_all(&buf).unwrap();
    }

    #[test]
    fn roundtrip_handbuilt() {
        let dir = std::env::temp_dir().join("mcma_dstest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.bin");
        write_ds(&path, 3, 2, 1, &[]);
        let ds = Dataset::load(&path).unwrap();
        assert_eq!((ds.n, ds.d_in, ds.d_out), (3, 2, 1));
        assert_eq!(ds.x_row(1), &[2.0, 3.0]);
        assert_eq!(ds.y_row(2), &[102.0]);
        let t = ds.truncated(2);
        assert_eq!(t.n, 2);
        assert_eq!(t.x_raw.len(), 4);
    }

    /// The write path round-trips through the reader, including the
    /// exact-EOF check.
    #[test]
    fn write_path_roundtrips() {
        let dir = std::env::temp_dir().join("mcma_dstest_write");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("w_{}.bin", std::process::id()));
        let ds = Dataset {
            n: 3,
            d_in: 2,
            d_out: 1,
            x_raw: vec![0.5, -1.25, 2.0, 3.5, -0.75, 8.0],
            y_norm: vec![0.1, 0.9, 0.5],
        };
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(ds, back, "dataset did not round-trip bitwise");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_trailing_garbage() {
        let dir = std::env::temp_dir().join("mcma_dstest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.bin");
        write_ds(&path, 1, 1, 1, b"junk");
        assert!(Dataset::load(&path).is_err());
    }
}
