//! Length-prefixed binary wire format for the TCP serving front-end.
//!
//! Every frame is a little-endian `u32` payload length followed by the
//! payload.  Payload layouts (all integers little-endian):
//!
//! ```text
//! request    [ver u8][kind=1 u8][tag u16][id u64][row f32 × d_in]
//! response   [ver u8][kind=2 u8][route u16][batch_n u16][id u64][y f32 × d_out]
//! stats req  [ver u8][kind=3 u8][tag u16][id u64]                (no row)
//! stats resp [ver u8][kind=3 u8][json bytes]
//! ```
//!
//! * `ver` is [`FRAME_VERSION`]; a mismatch is malformed.
//! * `tag` is the tenant/bench tag (single-tenant servers use 0 and
//!   reject anything else) — the multi-tenant hook without a v2 format.
//! * `route` is the approximator class that served the row, or
//!   [`ROUTE_CPU`] for the precise path.
//! * `batch_n` is how many rows shared the dispatch batch — the
//!   micro-batching observable `bench-load` histograms client-side.
//! * `id` is opaque to the server and echoed verbatim: clients pick any
//!   correlation scheme they like.
//! * a stats request is exactly a request header with `kind = 3`; the
//!   reply is the live [`crate::obs`] JSON snapshot.  Stats responses
//!   are the one frame whose payload is not f32-row-shaped, so they are
//!   decoded by [`decode_stats_response`] (and read client-side by
//!   [`read_stats_response`], which allows up to [`MAX_STATS_BYTES`]),
//!   never by `check_head`'s multiple-of-4 rule.
//!
//! Malformed or oversized frames are connection-fatal, never
//! process-fatal: [`FrameError::Malformed`] tells the listener to drop
//! that one connection and keep serving the rest.
//!
//! [`FrameReader`] is the incremental decoder both ends use: it
//! preserves partial progress across `WouldBlock`/`TimedOut` reads (the
//! listener runs sockets with a short read timeout so threads can check
//! the stop flag), which is what keeps a byte-at-a-time peer from ever
//! desyncing the stream.

// audit:connection-facing — a hostile frame must kill only its own
// connection; mcma-audit bans panics and unchecked indexing here.

use std::io::{self, Read};

/// Protocol version byte; bumped on any layout change.
pub const FRAME_VERSION: u8 = 1;

/// Payload kind bytes.
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
/// In-band observability scrape (request AND response use this kind).
pub const KIND_STATS: u8 = 3;

/// `route` wire value for the precise CPU path (approximator classes are
/// their index, so `u16::MAX` can never collide).
pub const ROUTE_CPU: u16 = u16::MAX;

/// Hard cap on f32 elements per row — far above any real workload
/// (paper benches are ≤ 6 inputs) but small enough that a hostile
/// length prefix cannot make the server allocate unboundedly.
pub const MAX_ROW_ELEMS: usize = 4096;

/// Hard cap on a whole payload: the largest legal header plus a full
/// row.  Anything bigger is malformed before a single payload byte is
/// read.
pub const MAX_FRAME_BYTES: usize = RESP_HEADER + 4 * MAX_ROW_ELEMS;

const REQ_HEADER: usize = 1 + 1 + 2 + 8;
const RESP_HEADER: usize = 1 + 1 + 2 + 2 + 8;

/// Hard cap on a stats RESPONSE payload (the JSON snapshot).  Stats
/// replies only flow server → client and are read with the dedicated
/// [`read_stats_response`], so this cap can exceed [`MAX_FRAME_BYTES`]
/// without widening what the server-side readers will accept.
pub const MAX_STATS_BYTES: usize = 2 + 64 * 1024;

/// Frame-layer failure.  `Io` is transport trouble (peer gone); both
/// variants kill the one connection they occurred on.
#[derive(Debug)]
pub enum FrameError {
    Io(io::Error),
    Malformed(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame io: {e}"),
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> Self {
        FrameError::Io(e)
    }
}

fn malformed(msg: impl Into<String>) -> FrameError {
    FrameError::Malformed(msg.into())
}

/// Decoded request header (row payload goes to the caller's buffer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestHead {
    pub tag: u16,
    pub id: u64,
}

/// Decoded response header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResponseHead {
    pub route: u16,
    pub batch_n: u16,
    pub id: u64,
}

/// Encode a request frame (length prefix included) into `buf`,
/// clearing it first — callers keep one buffer per connection so the
/// steady-state write path allocates nothing.
pub fn encode_request(buf: &mut Vec<u8>, tag: u16, id: u64, row: &[f32]) {
    assert!(row.len() <= MAX_ROW_ELEMS, "row exceeds MAX_ROW_ELEMS");
    buf.clear();
    let len = (REQ_HEADER + 4 * row.len()) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(FRAME_VERSION);
    buf.push(KIND_REQUEST);
    buf.extend_from_slice(&tag.to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    for v in row {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a response frame (length prefix included) into `buf`,
/// clearing it first.
pub fn encode_response(buf: &mut Vec<u8>, route: u16, batch_n: u16, id: u64, y: &[f32]) {
    assert!(y.len() <= MAX_ROW_ELEMS, "row exceeds MAX_ROW_ELEMS");
    buf.clear();
    let len = (RESP_HEADER + 4 * y.len()) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(FRAME_VERSION);
    buf.push(KIND_RESPONSE);
    buf.extend_from_slice(&route.to_le_bytes());
    buf.extend_from_slice(&batch_n.to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
    for v in y {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn check_head(payload: &[u8], kind: u8, header: usize) -> Result<(), FrameError> {
    if payload.len() < header {
        return Err(malformed(format!(
            "payload {} bytes, header needs {header}",
            payload.len()
        )));
    }
    let (ver, got_kind) = match payload {
        &[ver, k, ..] => (ver, k),
        _ => return Err(malformed("payload shorter than 2 bytes")),
    };
    if ver != FRAME_VERSION {
        return Err(malformed(format!(
            "version {ver} (expected {FRAME_VERSION})"
        )));
    }
    if got_kind != kind {
        return Err(malformed(format!("kind {got_kind} (expected {kind})")));
    }
    if (payload.len() - header) % 4 != 0 {
        return Err(malformed("row bytes not a multiple of 4"));
    }
    Ok(())
}

/// Little-endian header fields, with a short slice reported as malformed
/// instead of panicking.
fn get_u16(b: &[u8], at: usize) -> Result<u16, FrameError> {
    match b.get(at..at + 2) {
        Some(&[lo, hi]) => Ok(u16::from_le_bytes([lo, hi])),
        _ => Err(malformed("truncated u16 header field")),
    }
}

fn get_u64(b: &[u8], at: usize) -> Result<u64, FrameError> {
    match b.get(at..at + 8) {
        Some(s) => {
            let mut le = [0u8; 8];
            le.copy_from_slice(s); // `get(at..at + 8)` yielded exactly 8 bytes
            Ok(u64::from_le_bytes(le))
        }
        None => Err(malformed("truncated u64 header field")),
    }
}

fn read_f32s(bytes: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        let mut le = [0u8; 4];
        le.copy_from_slice(c); // chunks_exact(4) yields exactly 4 bytes
        out.push(f32::from_le_bytes(le));
    }
}

/// Decode a request payload (no length prefix); the row lands in
/// `row_out` (cleared first, f32s copied out of the unaligned wire
/// bytes).
pub fn decode_request(payload: &[u8], row_out: &mut Vec<f32>) -> Result<RequestHead, FrameError> {
    check_head(payload, KIND_REQUEST, REQ_HEADER)?;
    let tag = get_u16(payload, 2)?;
    let id = get_u64(payload, 4)?;
    read_f32s(payload.get(REQ_HEADER..).unwrap_or(&[]), row_out);
    Ok(RequestHead { tag, id })
}

/// Decode a response payload (no length prefix).
pub fn decode_response(payload: &[u8], y_out: &mut Vec<f32>) -> Result<ResponseHead, FrameError> {
    check_head(payload, KIND_RESPONSE, RESP_HEADER)?;
    let route = get_u16(payload, 2)?;
    let batch_n = get_u16(payload, 4)?;
    let id = get_u64(payload, 6)?;
    read_f32s(payload.get(RESP_HEADER..).unwrap_or(&[]), y_out);
    Ok(ResponseHead { route, batch_n, id })
}

/// Encode a stats request (length prefix included): a bare request
/// header with [`KIND_STATS`] and no row.
pub fn encode_stats_request(buf: &mut Vec<u8>, tag: u16, id: u64) {
    buf.clear();
    buf.extend_from_slice(&(REQ_HEADER as u32).to_le_bytes());
    buf.push(FRAME_VERSION);
    buf.push(KIND_STATS);
    buf.extend_from_slice(&tag.to_le_bytes());
    buf.extend_from_slice(&id.to_le_bytes());
}

/// Decode a stats request payload — exactly a request header, nothing
/// after it (a stats frame carrying extra bytes is malformed).
pub fn decode_stats_request(payload: &[u8]) -> Result<RequestHead, FrameError> {
    if payload.len() != REQ_HEADER {
        return Err(malformed(format!(
            "stats request is {} bytes (expected {REQ_HEADER})",
            payload.len()
        )));
    }
    check_head(payload, KIND_STATS, REQ_HEADER)?;
    let tag = get_u16(payload, 2)?;
    let id = get_u64(payload, 4)?;
    Ok(RequestHead { tag, id })
}

/// Encode a stats response (length prefix included): `[ver][kind=3]`
/// followed by raw JSON bytes.
pub fn encode_stats_response(buf: &mut Vec<u8>, json_bytes: &[u8]) {
    assert!(
        json_bytes.len() <= MAX_STATS_BYTES - 2,
        "stats snapshot exceeds MAX_STATS_BYTES"
    );
    buf.clear();
    let len = (2 + json_bytes.len()) as u32;
    buf.extend_from_slice(&len.to_le_bytes());
    buf.push(FRAME_VERSION);
    buf.push(KIND_STATS);
    buf.extend_from_slice(json_bytes);
}

/// Decode a stats response payload, returning the JSON bytes.  Total on
/// any input: the payload is length-arbitrary by design, so only the
/// version and kind bytes are validated.
pub fn decode_stats_response(payload: &[u8]) -> Result<&[u8], FrameError> {
    let (ver, kind) = match payload {
        &[ver, k, ..] => (ver, k),
        _ => return Err(malformed("stats payload shorter than 2 bytes")),
    };
    if ver != FRAME_VERSION {
        return Err(malformed(format!(
            "version {ver} (expected {FRAME_VERSION})"
        )));
    }
    if kind != KIND_STATS {
        return Err(malformed(format!("kind {kind} (expected {KIND_STATS})")));
    }
    Ok(payload.get(2..).unwrap_or(&[]))
}

/// Blocking read of one stats response frame into `out` (the payload,
/// prefix stripped) — the dedicated client-side reader: stats replies
/// may exceed [`MAX_FRAME_BYTES`], which the row-sized [`FrameReader`]
/// would reject.
pub fn read_stats_response(r: &mut impl Read, out: &mut Vec<u8>) -> Result<(), FrameError> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len < 2 || len > MAX_STATS_BYTES {
        return Err(malformed(format!(
            "stats frame length {len} outside [2, {MAX_STATS_BYTES}]"
        )));
    }
    out.clear();
    out.resize(len, 0);
    r.read_exact(out.as_mut_slice())?;
    Ok(())
}

/// Route ↔ wire mapping.
pub fn route_to_wire(route: crate::coordinator::Route) -> u16 {
    match route {
        crate::coordinator::Route::Approx(k) => k as u16,
        crate::coordinator::Route::Cpu => ROUTE_CPU,
    }
}

pub fn wire_to_route(w: u16) -> crate::coordinator::Route {
    if w == ROUTE_CPU {
        crate::coordinator::Route::Cpu
    } else {
        crate::coordinator::Route::Approx(w as usize)
    }
}

/// One `FrameReader::poll` outcome.
#[derive(Debug, PartialEq, Eq)]
pub enum FramePoll {
    /// A complete payload is available via [`FrameReader::payload`].
    Frame,
    /// The read timed out / would block; partial progress is retained —
    /// call `poll` again (after checking your stop flag).
    Pending,
    /// Clean EOF on a frame boundary: the peer finished sending.
    Closed,
}

/// Incremental frame decoder that survives short reads and read
/// timeouts without losing its place in the stream.
#[derive(Debug, Default)]
pub struct FrameReader {
    len_buf: [u8; 4],
    len_got: usize,
    payload: Vec<u8>,
    payload_got: usize,
    /// `Some(len)` once the prefix is fully read and validated.
    want: Option<usize>,
}

impl FrameReader {
    pub fn new() -> Self {
        Self::default()
    }

    /// The completed payload after `poll` returned [`FramePoll::Frame`].
    pub fn payload(&self) -> &[u8] {
        let n = self.want.unwrap_or(0);
        self.payload.get(..n).unwrap_or(&[])
    }

    /// Advance the decoder by reading from `r`.  EOF mid-frame is
    /// malformed; EOF on a frame boundary is [`FramePoll::Closed`].
    /// After [`FramePoll::Frame`], the next `poll` starts the next
    /// frame.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<FramePoll, FrameError> {
        // Returning a completed frame resets for the next one.
        if let Some(len) = self.want {
            if self.payload_got == len && len > 0 {
                self.want = None;
                self.len_got = 0;
                self.payload_got = 0;
            }
        }
        // Phase 1: the 4-byte length prefix.
        while self.want.is_none() {
            // audit:allow(panic-free-net) — len_got < 4 inside this loop: reaching 4 sets `want` and exits it
            match r.read(&mut self.len_buf[self.len_got..]) {
                Ok(0) => {
                    if self.len_got == 0 {
                        return Ok(FramePoll::Closed);
                    }
                    return Err(malformed("eof inside length prefix"));
                }
                Ok(n) => {
                    self.len_got += n;
                    if self.len_got == 4 {
                        let len = u32::from_le_bytes(self.len_buf) as usize;
                        if len < 2 || len > MAX_FRAME_BYTES {
                            return Err(malformed(format!(
                                "frame length {len} outside [2, {MAX_FRAME_BYTES}]"
                            )));
                        }
                        if self.payload.len() < len {
                            self.payload.resize(len, 0);
                        }
                        self.want = Some(len);
                        self.payload_got = 0;
                    }
                }
                Err(e) if retryable(&e) => return Ok(FramePoll::Pending),
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        // Phase 2: the payload.
        let Some(len) = self.want else {
            // Phase 1 always leaves `want` set; stay total anyway.
            return Err(malformed("frame reader lost its length state"));
        };
        while self.payload_got < len {
            // audit:allow(panic-free-net) — payload was resized to `len` when the prefix was accepted
            match r.read(&mut self.payload[self.payload_got..len]) {
                Ok(0) => return Err(malformed("eof inside payload")),
                Ok(n) => self.payload_got += n,
                Err(e) if retryable(&e) => return Ok(FramePoll::Pending),
                Err(e) => return Err(FrameError::Io(e)),
            }
        }
        Ok(FramePoll::Frame)
    }
}

/// Read errors that mean "try again later", not "connection broken".
/// Linux reports a `read` timeout as `WouldBlock`; other platforms use
/// `TimedOut`; `Interrupted` is a stray signal.
fn retryable(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Route;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        let row = [1.0f32, -2.5, 3.25];
        encode_request(&mut buf, 7, 42, &row);
        // Length prefix covers exactly the payload.
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4);
        let mut out = Vec::new();
        let head = decode_request(&buf[4..], &mut out).unwrap();
        assert_eq!(head, RequestHead { tag: 7, id: 42 });
        assert_eq!(out, row);
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        let y = [0.125f32, 9.0];
        encode_response(&mut buf, 3, 8, u64::MAX, &y);
        let mut out = Vec::new();
        let head = decode_response(&buf[4..], &mut out).unwrap();
        assert_eq!(head, ResponseHead { route: 3, batch_n: 8, id: u64::MAX });
        assert_eq!(out, y);
    }

    #[test]
    fn stats_request_roundtrip() {
        let mut buf = Vec::new();
        encode_stats_request(&mut buf, 5, 0xDEAD_BEEF);
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4);
        assert_eq!(len, 12); // bare request header, no row
        let head = decode_stats_request(&buf[4..]).unwrap();
        assert_eq!(head, RequestHead { tag: 5, id: 0xDEAD_BEEF });
    }

    #[test]
    fn stats_response_roundtrip() {
        let mut buf = Vec::new();
        let json = br#"{"uptime_s":1.5,"counters":{}}"#;
        encode_stats_response(&mut buf, json);
        // Deliberately NOT a multiple of 4 after the 2-byte head: stats
        // responses bypass check_head's row-shape rule.
        let payload = &buf[4..];
        assert_eq!(decode_stats_response(payload).unwrap(), json);

        let mut out = Vec::new();
        read_stats_response(&mut Cursor::new(buf.clone()), &mut out).unwrap();
        assert_eq!(decode_stats_response(&out).unwrap(), json);
    }

    #[test]
    fn stats_frames_reject_malformed() {
        let mut buf = Vec::new();
        encode_stats_request(&mut buf, 0, 1);

        // Extra trailing bytes on a stats request are malformed.
        let mut long = buf[4..].to_vec();
        long.extend_from_slice(&[0, 0, 0, 0]);
        assert!(matches!(
            decode_stats_request(&long),
            Err(FrameError::Malformed(_))
        ));

        // Wrong version / wrong kind.
        let mut bad = buf[4..].to_vec();
        bad[0] = 9;
        assert!(matches!(
            decode_stats_request(&bad),
            Err(FrameError::Malformed(_))
        ));
        let mut bad = buf[4..].to_vec();
        bad[1] = KIND_REQUEST;
        assert!(matches!(
            decode_stats_request(&bad),
            Err(FrameError::Malformed(_))
        ));
        assert!(matches!(
            decode_stats_response(&bad),
            Err(FrameError::Malformed(_))
        ));
        assert!(matches!(
            decode_stats_response(&[FRAME_VERSION]),
            Err(FrameError::Malformed(_))
        ));

        // A stats request still fits through the row-sized FrameReader
        // (12 bytes << MAX_FRAME_BYTES) — the server reads it in-band.
        let mut fr = FrameReader::new();
        let mut cur = Cursor::new(buf.clone());
        assert_eq!(fr.poll(&mut cur).unwrap(), FramePoll::Frame);
        assert_eq!(fr.payload().get(1), Some(&KIND_STATS));

        // Hostile stats-reply length prefix is rejected by the client
        // reader before any oversized allocation.
        let huge = ((MAX_STATS_BYTES + 1) as u32).to_le_bytes();
        let mut out = Vec::new();
        assert!(matches!(
            read_stats_response(&mut Cursor::new(huge.to_vec()), &mut out),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn route_wire_mapping_roundtrips() {
        for r in [Route::Approx(0), Route::Approx(5), Route::Cpu] {
            assert_eq!(wire_to_route(route_to_wire(r)), r);
        }
        assert_eq!(route_to_wire(Route::Cpu), ROUTE_CPU);
    }

    #[test]
    fn rejects_bad_version_kind_and_shape() {
        let mut buf = Vec::new();
        encode_request(&mut buf, 0, 1, &[1.0]);
        let mut out = Vec::new();

        let mut bad = buf[4..].to_vec();
        bad[0] = 99;
        assert!(matches!(
            decode_request(&bad, &mut out),
            Err(FrameError::Malformed(_))
        ));

        let mut bad = buf[4..].to_vec();
        bad[1] = KIND_RESPONSE;
        assert!(matches!(
            decode_request(&bad, &mut out),
            Err(FrameError::Malformed(_))
        ));

        // Truncated header and ragged row bytes are both malformed.
        assert!(matches!(
            decode_request(&buf[4..9], &mut out),
            Err(FrameError::Malformed(_))
        ));
        let mut ragged = buf[4..].to_vec();
        ragged.push(0);
        assert!(matches!(
            decode_request(&ragged, &mut out),
            Err(FrameError::Malformed(_))
        ));
    }

    /// A `Read` that yields one byte per call, interleaving WouldBlock —
    /// the worst-case peer for an incremental decoder.
    struct Trickle {
        data: Vec<u8>,
        pos: usize,
        block_next: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.block_next {
                self.block_next = false;
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "later"));
            }
            self.block_next = true;
            if self.pos == self.data.len() {
                return Ok(0);
            }
            out[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn frame_reader_survives_byte_at_a_time_reads() {
        let mut wire = Vec::new();
        let mut frame = Vec::new();
        encode_request(&mut frame, 0, 11, &[1.0, 2.0]);
        wire.extend_from_slice(&frame);
        encode_request(&mut frame, 0, 12, &[3.0]);
        wire.extend_from_slice(&frame);

        let mut r = Trickle { data: wire, pos: 0, block_next: false };
        let mut fr = FrameReader::new();
        let mut row = Vec::new();
        let mut ids = Vec::new();
        loop {
            match fr.poll(&mut r).unwrap() {
                FramePoll::Frame => {
                    let head = decode_request(fr.payload(), &mut row).unwrap();
                    ids.push((head.id, row.clone()));
                }
                FramePoll::Pending => continue,
                FramePoll::Closed => break,
            }
        }
        assert_eq!(ids, vec![(11, vec![1.0, 2.0]), (12, vec![3.0])]);
    }

    #[test]
    fn frame_reader_rejects_oversized_and_mid_frame_eof() {
        // Hostile length prefix: rejected before any payload allocation
        // beyond the cap.
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        let mut fr = FrameReader::new();
        assert!(matches!(
            fr.poll(&mut Cursor::new(huge.to_vec())),
            Err(FrameError::Malformed(_))
        ));

        // EOF halfway through a declared payload is malformed, not Closed.
        let mut frame = Vec::new();
        encode_request(&mut frame, 0, 5, &[1.0, 2.0, 3.0]);
        frame.truncate(frame.len() - 3);
        let mut fr = FrameReader::new();
        let mut cur = Cursor::new(frame);
        let err = loop {
            match fr.poll(&mut cur) {
                Ok(FramePoll::Frame) => panic!("truncated frame decoded"),
                Ok(_) => continue,
                Err(e) => break e,
            }
        };
        assert!(matches!(err, FrameError::Malformed(_)));
    }
}
