//! Seeded closed/open-loop load generator (`mcma bench-load`).
//!
//! Modeled on edgeless_benchmark's seeded workload populations: every
//! stochastic choice — which mix class a request belongs to, which
//! held-out row it carries, the Poisson interarrival gaps — comes from
//! dedicated `util::rng` splitmix64 streams of the one `--seed`, so two
//! runs with the same seed generate **identical request sequences**
//! regardless of how the server happens to interleave responses.
//!
//! * **Mix classes** partition the served workload's held-out set into
//!   `mix.len()` equal contiguous shards; a request first draws its
//!   class (weighted), then a row uniformly inside that shard.  With a
//!   single weight the whole set is one class.
//! * **Closed loop** keeps exactly `inflight` requests outstanding
//!   (credit tokens recycled by the receiver) — the arrival model that
//!   lets the server's micro-batcher show coalescing.
//! * **Open loop** fires at `rate_hz` with exponential interarrivals,
//!   never waiting for responses — the overload-probing model.
//!
//! The report carries client-observed p50/p99/p999, per-route counts,
//! the batch-size histogram (from the `batch_n` response field), and
//! QoS violations scored client-side: each response is compared against
//! the held-out row's recorded label with the same `row_rmse` the QoS
//! controller uses.

use std::io::Write;
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{LatencyStats, PerRouteReport};
use crate::formats::Dataset;
use crate::util::rng::{splitmix64, Rng};

use super::frame::{
    decode_response, decode_stats_response, encode_request, encode_stats_request,
    read_stats_response, wire_to_route, FramePoll, FrameReader,
};

/// Arrival model.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Poisson arrivals at `rate_hz`, regardless of outstanding count.
    OpenLoop { rate_hz: f64 },
    /// Exactly `inflight` requests outstanding at all times.
    ClosedLoop { inflight: usize },
}

#[derive(Clone, Debug)]
pub struct LoadConfig {
    /// Server address, e.g. `127.0.0.1:7090`.
    pub addr: String,
    pub seed: u64,
    /// Stop issuing new requests after this long.
    pub duration: Duration,
    /// Also stop after this many requests (`None` = duration only).
    /// Same-seed runs with the same cap are bit-identical end to end.
    pub max_requests: Option<u64>,
    pub arrival: Arrival,
    /// Mix-class weights; the held-out set is split into `mix.len()`
    /// equal contiguous shards.  Empty means one uniform class.
    pub mix: Vec<f64>,
    /// Tenant tag to stamp on every request.
    pub tag: u16,
    /// Client-side QoS error bound: a response whose RMSE against the
    /// held-out label exceeds this counts as a violation.
    pub qos_target: f64,
}

/// One request's full client-side record (CSV row).
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub class: usize,
    pub row: usize,
    /// Filled in by the receiver; `None` = never answered.
    pub latency_us: Option<f64>,
    pub route: Option<u16>,
    pub batch_n: u16,
    pub err: f64,
    pub violation: bool,
}

/// Aggregate load-run outcome.
#[derive(Debug)]
pub struct LoadReport {
    pub sent: u64,
    pub received: u64,
    pub violations: u64,
    pub wall: Duration,
    pub latency: LatencyStats,
    pub per_route: PerRouteReport,
    /// Client-observed dispatch batch sizes (`batch_hist[n]` = responses
    /// whose batch had exactly `n` rows).
    pub batch_hist: Vec<u64>,
    pub per_class_sent: Vec<u64>,
    /// Per-request records in send order (CSV source).
    pub records: Vec<RequestRecord>,
    /// Server-side observability snapshot scraped mid-run over a second
    /// connection (in-band STATS frame) — the stage waterfall as the
    /// server saw it while this load was live.  `None` when the scrape
    /// connection failed (e.g. pre-STATS server).
    pub stats_snapshot: Option<crate::util::json::Value>,
}

impl LoadReport {
    pub fn rows_per_sec(&self) -> f64 {
        if self.wall.as_secs_f64() == 0.0 {
            0.0
        } else {
            self.received as f64 / self.wall.as_secs_f64()
        }
    }

    /// Responses served in batches of more than one row.
    pub fn multi_row_responses(&self) -> u64 {
        self.batch_hist.iter().skip(2).sum()
    }

    /// Write the per-request CSV (send order; one line per request).
    pub fn write_csv(&self, path: &Path) -> crate::Result<()> {
        let mut out = String::new();
        out.push_str("req,class,row,route,batch_n,latency_us,err,violation\n");
        for (i, r) in self.records.iter().enumerate() {
            let route = match r.route {
                Some(w) => format!("{w}"),
                None => "-".into(),
            };
            let latency = match r.latency_us {
                Some(us) => format!("{us:.1}"),
                None => "-".into(),
            };
            out.push_str(&format!(
                "{i},{},{},{route},{},{latency},{:.6},{}\n",
                r.class, r.row, r.batch_n, r.err, u8::from(r.violation)
            ));
        }
        std::fs::write(path, out)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

/// Shared sender/receiver state: per-request metadata appended in send
/// order, results filled in on response arrival.
struct Flight {
    sent_at: Vec<Instant>,
    records: Vec<RequestRecord>,
    received: u64,
    violations: u64,
    latency: LatencyStats,
    per_route: PerRouteReport,
    batch_hist: Vec<u64>,
}

/// Draw the request sequence deterministically: class (weighted by
/// `mix`), then row uniform within the class's contiguous shard.
/// Consuming this sequentially is what makes same-seed runs identical.
fn draw_request(rng: &mut Rng, mix: &[f64], mix_total: f64, n_rows: usize) -> (usize, usize) {
    let classes = mix.len();
    let mut class = classes - 1;
    let mut acc = 0.0;
    let u = rng.f64() * mix_total;
    for (c, w) in mix.iter().enumerate() {
        acc += w;
        if u < acc {
            class = c;
            break;
        }
    }
    let lo = class * n_rows / classes;
    let hi = ((class + 1) * n_rows / classes).max(lo + 1);
    let row = lo + rng.below((hi - lo) as u64) as usize;
    (class, row)
}

/// Scrape a live server's observability snapshot over its own short
/// connection: send one in-band STATS frame, read back the JSON reply.
/// Used mid-run by [`run_load`] and directly by `mcma stats`.
pub fn scrape_stats(addr: &str, tag: u16) -> crate::Result<crate::util::json::Value> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting {addr}: {e}"))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    let mut buf = Vec::new();
    encode_stats_request(&mut buf, tag, 0);
    stream
        .write_all(&buf)
        .map_err(|e| anyhow::anyhow!("sending STATS to {addr}: {e}"))?;
    let mut payload = Vec::new();
    read_stats_response(&mut stream, &mut payload)
        .map_err(|e| anyhow::anyhow!("reading STATS reply from {addr}: {e}"))?;
    let json_bytes = decode_stats_response(&payload)
        .map_err(|e| anyhow::anyhow!("decoding STATS reply from {addr}: {e}"))?;
    let text = std::str::from_utf8(json_bytes)
        .map_err(|e| anyhow::anyhow!("STATS reply from {addr} is not UTF-8: {e}"))?;
    crate::util::json::parse(text)
        .map_err(|e| anyhow::anyhow!("STATS reply from {addr} is not JSON: {e}"))
}

/// Run the load against a live server.  `held_out` is the served
/// workload's held-out dataset — the row source and the violation
/// oracle.
pub fn run_load(cfg: &LoadConfig, held_out: &Arc<Dataset>) -> crate::Result<LoadReport> {
    anyhow::ensure!(held_out.n > 0, "held-out dataset is empty");
    let mix: Vec<f64> = if cfg.mix.is_empty() { vec![1.0] } else { cfg.mix.clone() };
    anyhow::ensure!(
        mix.iter().all(|w| *w >= 0.0) && mix.iter().sum::<f64>() > 0.0,
        "mix weights must be non-negative with a positive sum"
    );
    anyhow::ensure!(
        mix.len() <= held_out.n,
        "more mix classes ({}) than held-out rows ({})",
        mix.len(),
        held_out.n
    );
    let mix_total: f64 = mix.iter().sum();

    let stream = TcpStream::connect(&cfg.addr)
        .map_err(|e| anyhow::anyhow!("connecting {}: {e}", cfg.addr))?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(Duration::from_millis(25)))?;
    let mut write_half = stream.try_clone()?;

    let flight = Arc::new(Mutex::new(Flight {
        sent_at: Vec::new(),
        records: Vec::new(),
        received: 0,
        violations: 0,
        latency: LatencyStats::default(),
        per_route: PerRouteReport::default(),
        batch_hist: Vec::new(),
    }));
    let done_sending = Arc::new(AtomicBool::new(false));
    // Closed-loop credit tokens: the receiver returns one per response.
    let (credit_tx, credit_rx) = mpsc::channel::<()>();

    // Receiver thread: decode responses, score against held-out labels,
    // record latency/route/batch size.
    let receiver = {
        let flight = Arc::clone(&flight);
        let done_sending = Arc::clone(&done_sending);
        let held_out = Arc::clone(held_out);
        let credit_tx = credit_tx.clone();
        let qos_target = cfg.qos_target;
        let mut read_half = stream;
        thread::Builder::new().name("mcma-load-recv".into()).spawn(move || {
            let mut fr = FrameReader::new();
            let mut y = Vec::new();
            let mut idle_since = Instant::now();
            loop {
                match fr.poll(&mut read_half) {
                    Ok(FramePoll::Frame) => {
                        idle_since = Instant::now();
                        let head = match decode_response(fr.payload(), &mut y) {
                            Ok(h) => h,
                            Err(_) => return,
                        };
                        let now = Instant::now();
                        let mut f = flight.lock().unwrap();
                        let i = head.id as usize;
                        if i >= f.records.len() {
                            return; // protocol violation: unknown id
                        }
                        let us =
                            now.duration_since(f.sent_at[i]).as_secs_f64() * 1e6;
                        let err =
                            crate::qos::row_rmse(&y, held_out.y_row(f.records[i].row));
                        let violation = err > qos_target;
                        {
                            let rec = &mut f.records[i];
                            rec.latency_us = Some(us);
                            rec.route = Some(head.route);
                            rec.batch_n = head.batch_n;
                            rec.err = err;
                            rec.violation = violation;
                        }
                        f.received += 1;
                        f.violations += u64::from(violation);
                        f.latency.push(us);
                        f.per_route.push(wire_to_route(head.route), us);
                        let b = head.batch_n as usize;
                        if f.batch_hist.len() <= b {
                            f.batch_hist.resize(b + 1, 0);
                        }
                        f.batch_hist[b] += 1;
                        let outstanding_done = done_sending.load(Ordering::Acquire)
                            && f.received == f.records.len() as u64;
                        drop(f);
                        let _ = credit_tx.send(());
                        if outstanding_done {
                            return;
                        }
                    }
                    Ok(FramePoll::Pending) => {
                        // Once the sender finished: leave immediately if
                        // every request is answered, else give the tail
                        // 2 s of quiet before declaring the rest lost.
                        if done_sending.load(Ordering::Acquire) {
                            let complete = {
                                let f = flight.lock().unwrap();
                                f.received == f.records.len() as u64
                            };
                            if complete || idle_since.elapsed() > Duration::from_secs(2) {
                                return;
                            }
                        }
                    }
                    Ok(FramePoll::Closed) | Err(_) => return,
                }
            }
        })?
    };

    // Sender (this thread): one splitmix64 stream for the request
    // sequence, an independent one for arrival timing — timing noise
    // can never perturb WHICH requests are generated.
    let mut seq_rng = Rng::new(splitmix64(cfg.seed ^ 0x5eed_5eed_0000_0001));
    let mut gap_rng = Rng::new(splitmix64(cfg.seed ^ 0x5eed_5eed_0000_0002));
    let started = Instant::now();
    let stop_at = started + cfg.duration;
    let mut sent = 0u64;
    let mut per_class_sent = vec![0u64; mix.len()];
    let mut buf = Vec::new();
    // Mid-run stage-waterfall scrape: fire once past the halfway point
    // (by request cap when set, else by wall clock) so the snapshot
    // reflects the server under this load, not its idle tail.  The
    // scrape rides its own connection and its own RNG-free path, so it
    // cannot perturb the seeded request sequence.
    let scrape_after = started + cfg.duration / 2;
    let mut stats_snapshot: Option<crate::util::json::Value> = None;

    if let Arrival::ClosedLoop { inflight } = cfg.arrival {
        for _ in 0..inflight.max(1) {
            let _ = credit_tx.send(());
        }
    }
    let mut next_fire = started;

    loop {
        if Instant::now() >= stop_at {
            break;
        }
        if let Some(cap) = cfg.max_requests {
            if sent >= cap {
                break;
            }
        }
        match cfg.arrival {
            Arrival::ClosedLoop { .. } => {
                // Wait for a credit, re-checking the deadline.
                match credit_rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(()) => {}
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            Arrival::OpenLoop { rate_hz } => {
                let gap = -(1.0 - gap_rng.f64()).ln() / rate_hz.max(1e-9);
                next_fire += Duration::from_secs_f64(gap);
                let now = Instant::now();
                if next_fire > now {
                    thread::sleep(next_fire - now);
                }
            }
        }
        let (class, row) = draw_request(&mut seq_rng, &mix, mix_total, held_out.n);
        encode_request(&mut buf, cfg.tag, sent, held_out.x_row(row));
        {
            let mut f = flight.lock().unwrap();
            f.sent_at.push(Instant::now());
            f.records.push(RequestRecord {
                class,
                row,
                latency_us: None,
                route: None,
                batch_n: 0,
                err: 0.0,
                violation: false,
            });
        }
        if write_half.write_all(&buf).is_err() {
            // Roll the record back: it never reached the wire.
            let mut f = flight.lock().unwrap();
            f.sent_at.pop();
            f.records.pop();
            break;
        }
        per_class_sent[class] += 1;
        sent += 1;
        let halfway = match cfg.max_requests {
            Some(cap) => sent >= cap / 2,
            None => Instant::now() >= scrape_after,
        };
        if stats_snapshot.is_none() && halfway {
            stats_snapshot = scrape_stats(&cfg.addr, cfg.tag).ok();
        }
    }
    // Short runs can finish before the halfway trigger; scrape now while
    // the server is still hot (the receiver is still draining the tail).
    if stats_snapshot.is_none() {
        stats_snapshot = scrape_stats(&cfg.addr, cfg.tag).ok();
    }

    done_sending.store(true, Ordering::Release);
    drop(credit_tx);
    receiver
        .join()
        .map_err(|_| anyhow::anyhow!("load receiver thread panicked"))?;
    // Half-close our side so the server sees a clean EOF.
    let _ = write_half.shutdown(std::net::Shutdown::Write);
    let wall = started.elapsed();

    let f = Arc::try_unwrap(flight)
        .map_err(|_| anyhow::anyhow!("flight state still shared"))?
        .into_inner()
        .unwrap();
    Ok(LoadReport {
        sent,
        received: f.received,
        violations: f.violations,
        wall,
        latency: f.latency,
        per_route: f.per_route,
        batch_hist: f.batch_hist,
        per_class_sent,
        records: f.records,
        stats_snapshot,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same seed ⇒ identical (class, row) sequence; different seed ⇒
    /// (overwhelmingly) different.  This is the pure core the e2e
    /// same-seed CSV test rests on.
    #[test]
    fn request_sequence_is_seed_deterministic() {
        let mix = vec![3.0, 1.0];
        let total = 4.0;
        let draw_n = |seed: u64| -> Vec<(usize, usize)> {
            let mut rng = Rng::new(splitmix64(seed ^ 0x5eed_5eed_0000_0001));
            (0..200).map(|_| draw_request(&mut rng, &mix, total, 1000)).collect()
        };
        let a = draw_n(7);
        let b = draw_n(7);
        let c = draw_n(8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        // Shard discipline: class 0 rows in [0, 500), class 1 in [500, 1000).
        for (class, row) in &a {
            match class {
                0 => assert!(*row < 500),
                1 => assert!((500..1000).contains(row)),
                _ => panic!("impossible class"),
            }
        }
        // The 3:1 weighting shows up in the draw counts.
        let c0 = a.iter().filter(|(c, _)| *c == 0).count();
        assert!(c0 > 100, "class 0 should dominate a 3:1 mix, got {c0}/200");
    }

    #[test]
    fn csv_has_header_and_one_line_per_request() {
        let report = LoadReport {
            sent: 2,
            received: 1,
            violations: 0,
            wall: Duration::from_secs(1),
            latency: LatencyStats::default(),
            per_route: PerRouteReport::default(),
            batch_hist: vec![0, 1],
            per_class_sent: vec![2],
            records: vec![
                RequestRecord {
                    class: 0,
                    row: 5,
                    latency_us: Some(42.0),
                    route: Some(0),
                    batch_n: 1,
                    err: 0.001,
                    violation: false,
                },
                RequestRecord {
                    class: 0,
                    row: 9,
                    latency_us: None,
                    route: None,
                    batch_n: 0,
                    err: 0.0,
                    violation: false,
                },
            ],
            stats_snapshot: None,
        };
        let path = std::env::temp_dir().join(format!("mcma-load-{}.csv", std::process::id()));
        report.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("req,class,row,route"));
        assert!(lines[1].starts_with("0,0,5,0,1,42.0"));
        assert!(lines[2].contains(",-,"), "unanswered request marked with -");
    }
}
