//! Minimal HTTP/1.1 exposition responder (`serve --metrics-listen`).
//!
//! Serves exactly two routes:
//!
//! * `GET /metrics` — the OpenMetrics rendering of the live
//!   [`crate::obs::Registry`] snapshot ([`crate::obs::expo::render`]);
//! * `GET /healthz` — `200 ok` while the SLO burn-rate monitor is
//!   within budget (or no monitor is configured), `503` during a
//!   breach.
//!
//! Anything else — other paths, other methods — is a `404`.  A
//! malformed request (no parseable request line, oversized head, read
//! timeout) kills only its own connection, the same hardening contract
//! as the frame protocol in [`super::listener`]: the responder never
//! panics, never trusts peer bytes, and each connection is handled by a
//! short-lived thread so a stuck peer cannot stall the accept loop.
//!
//! This is deliberately not a general HTTP server: no keep-alive, no
//! chunked bodies, no header parsing beyond the request line — scrape
//! clients (Prometheus, curl) speak this subset happily.

// audit:connection-facing — a hostile peer must kill only its own
// connection; mcma-audit bans panics and unchecked indexing here.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::obs::expo;
use crate::obs::slo::SloMonitor;
use crate::obs::Obs;

/// Maximum request head we will buffer before declaring the peer
/// hostile and dropping the connection.
const MAX_HEAD: usize = 4096;

/// Per-connection socket timeouts: a scrape either completes promptly
/// or its connection dies without holding any shared state.
const IO_TIMEOUT: Duration = Duration::from_secs(2);

/// Running exposition endpoint; `shutdown` stops the accept loop.
pub struct MetricsServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (`127.0.0.1:0` for an ephemeral test port) and serve
    /// `/metrics` + `/healthz` from the shared observability handle.
    /// `slo` wires `/healthz` (and the `mcma_slo_*` families) to the
    /// burn-rate monitor when `serve` configured one.
    pub fn spawn(
        obs: Obs,
        slo: Option<Arc<SloMonitor>>,
        addr: &str,
    ) -> crate::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("binding metrics endpoint {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let accept_thread = {
            let stop = Arc::clone(&stop);
            thread::Builder::new().name("mcma-metrics-accept".into()).spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let stream = match listener.accept() {
                        Ok((s, _)) => s,
                        Err(_) => {
                            thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    // Accepted sockets do not reliably inherit the
                    // listener's flags; make them blocking with a bounded
                    // timeout explicitly.
                    if stream.set_nonblocking(false).is_err()
                        || stream.set_read_timeout(Some(IO_TIMEOUT)).is_err()
                        || stream.set_write_timeout(Some(IO_TIMEOUT)).is_err()
                    {
                        continue;
                    }
                    let obs = obs.clone();
                    let slo = slo.clone();
                    // Detached: each scrape connection is answered and
                    // closed; a failed spawn drops only this connection.
                    let _ = thread::Builder::new()
                        .name("mcma-metrics-conn".into())
                        .spawn(move || serve_connection(stream, &obs, slo.as_deref()));
                }
            })?
        };

        Ok(MetricsServer { local_addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting scrapes (in-flight connections finish on their
    /// own short timeouts).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Read one request head, answer it, close.  Every failure path simply
/// returns — the connection dies, the server does not.
fn serve_connection(mut stream: TcpStream, obs: &Obs, slo: Option<&SloMonitor>) {
    let mut head: Vec<u8> = Vec::new();
    let mut buf = [0u8; 1024];
    let complete = loop {
        match stream.read(&mut buf) {
            Ok(0) => break false,
            Ok(n) => {
                head.extend_from_slice(buf.get(..n).unwrap_or(&[]));
                if head.windows(4).any(|w| w == b"\r\n\r\n") {
                    break true;
                }
                if head.len() > MAX_HEAD {
                    break false;
                }
            }
            Err(_) => break false,
        }
    };
    if !complete {
        return; // malformed or hostile: kill only this connection
    }
    let Some((method, path)) = parse_request_line(&head) else {
        return;
    };
    let (status, content_type, body) = route(method, path, obs, slo);
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(header.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.shutdown(Shutdown::Both);
}

/// `(method, path)` from the request line, or `None` for anything that
/// is not `METHOD SP TARGET SP HTTP/1.x`.  The query string is dropped.
fn parse_request_line(head: &[u8]) -> Option<(&str, &str)> {
    let text = std::str::from_utf8(head).ok()?;
    let line = text.lines().next()?;
    let mut parts = line.split(' ');
    let method = parts.next()?;
    let target = parts.next()?;
    let version = parts.next()?;
    if !version.starts_with("HTTP/1.") || parts.next().is_some() {
        return None;
    }
    let path = target.split('?').next().unwrap_or(target);
    Some((method, path))
}

fn route(
    method: &str,
    path: &str,
    obs: &Obs,
    slo: Option<&SloMonitor>,
) -> (&'static str, &'static str, String) {
    const TEXT: &str = "text/plain; charset=utf-8";
    match (method, path) {
        ("GET", "/metrics") => ("200 OK", expo::CONTENT_TYPE, expo::render(obs, slo)),
        ("GET", "/healthz") => {
            if slo.map(SloMonitor::healthy).unwrap_or(true) {
                ("200 OK", TEXT, "ok\n".to_string())
            } else {
                ("503 Service Unavailable", TEXT, "slo breach\n".to_string())
            }
        }
        _ => ("404 Not Found", TEXT, "not found\n".to_string()),
    }
}

/// Tiny scrape client for tests, `bench-load`'s cross-check and CI:
/// one `GET`, returns `(status_code, body)`.
pub fn http_get(addr: &str, path: &str) -> crate::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting to metrics endpoint {addr}: {e}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8(raw)
        .map_err(|_| anyhow::anyhow!("metrics response is not UTF-8"))?;
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("metrics response has no header/body split"))?;
    let status_line = head
        .lines()
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty metrics response"))?;
    let code = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
    Ok((code, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::slo::SloConfig;

    fn spawn_test_server(slo: Option<Arc<SloMonitor>>) -> (MetricsServer, Obs) {
        let obs = Obs::new(1, 1.0);
        obs.metrics.submitted.add(3);
        let srv = MetricsServer::spawn(obs.clone(), slo, "127.0.0.1:0").expect("bind");
        (srv, obs)
    }

    #[test]
    fn serves_metrics_and_healthz() {
        let (srv, _obs) = spawn_test_server(None);
        let addr = srv.local_addr().to_string();
        let (code, body) = http_get(&addr, "/metrics").expect("scrape");
        assert_eq!(code, 200);
        assert!(body.contains("mcma_submitted_total 3"), "{body}");
        assert!(body.ends_with("# EOF\n"));
        let (code, body) = http_get(&addr, "/healthz").expect("healthz");
        assert_eq!(code, 200);
        assert_eq!(body, "ok\n");
        srv.shutdown();
    }

    #[test]
    fn unknown_paths_and_methods_get_404() {
        let (srv, _obs) = spawn_test_server(None);
        let addr = srv.local_addr().to_string();
        let (code, _) = http_get(&addr, "/nope").expect("get");
        assert_eq!(code, 404);
        // Non-GET: raw request, expect 404 per the route contract.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(b"POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        srv.shutdown();
    }

    #[test]
    fn malformed_request_kills_only_its_connection() {
        let (srv, _obs) = spawn_test_server(None);
        let addr = srv.local_addr().to_string();
        {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"\xff\xfe garbage\r\n\r\n").unwrap();
            let mut resp = Vec::new();
            let _ = s.read_to_end(&mut resp);
            assert!(resp.is_empty(), "malformed request must get no response");
        }
        // The endpoint still answers afterwards.
        let (code, _) = http_get(&addr, "/metrics").expect("scrape after garbage");
        assert_eq!(code, 200);
        srv.shutdown();
    }

    #[test]
    fn healthz_flips_503_on_slo_breach_and_recovers() {
        let cfg = SloConfig {
            short_window_us: 10_000_000,
            long_window_us: 60_000_000,
            fast_burn: 10.0,
            slow_burn: 2.0,
            ..SloConfig::new(1_000, 0.01)
        };
        let slo = Arc::new(SloMonitor::new(cfg));
        let (srv, _obs) = spawn_test_server(Some(Arc::clone(&slo)));
        let addr = srv.local_addr().to_string();
        let (code, _) = http_get(&addr, "/healthz").unwrap();
        assert_eq!(code, 200);
        // 50% bad at a 1% budget: burn 50 on both windows -> breach.
        let t = slo.tick(1_000_000, 1_000, 500);
        assert!(t.breached);
        let (code, body) = http_get(&addr, "/healthz").unwrap();
        assert_eq!(code, 503);
        assert_eq!(body, "slo breach\n");
        let (_, metrics) = http_get(&addr, "/metrics").unwrap();
        assert!(metrics.contains("mcma_slo_healthy 0"), "{metrics}");
        // Clean windows drain the burn; healthz recovers.
        let t = slo.tick(120_000_000, 101_000, 500);
        assert!(!t.breached);
        let (code, _) = http_get(&addr, "/healthz").unwrap();
        assert_eq!(code, 200);
        srv.shutdown();
    }
}
