//! TCP front-end over the in-process serving pipeline.
//!
//! ```text
//! client ──frames──► reader thread (per conn) ──Submitter──► Server ingress
//! client ◄─frames── response pump (owns Server) ◄─egress────┘
//! ```
//!
//! * The **accept loop** hands each connection a dedicated reader
//!   thread; readers decode request frames and feed the existing
//!   submit path through a [`Submitter`] — the batcher, workers and
//!   QoS controller are completely unchanged.
//! * The **response pump** is the single owner of the [`Server`]
//!   (its egress receiver is `!Sync`): it demultiplexes responses back
//!   to their connections, reusing one write buffer per connection so
//!   the steady-state write path allocates nothing.
//! * **Id mapping**: the client's `id` is opaque and echoed verbatim;
//!   internally each request travels as `(conn_id << 32) | slot`, where
//!   `slot` indexes a per-connection slab that remembers the client id.
//!   Slots are recycled, so the slab stops growing at the connection's
//!   high-water in-flight mark.
//!
//! ## Dead connections never stall the drain
//!
//! When a client disconnects with requests still in flight, its
//! responses continue to arrive at the pump, which counts them as
//! `delivery_failed`, skips the dead socket, and still adds them to the
//! collected set — so `Server::shutdown`'s exact
//! `submitted − collected − lost` accounting sees every frame owed to
//! the dead connection and finishes without ever touching its 2 s
//! last-resort timeout.  A malformed or oversized frame likewise kills
//! only its own connection ([`FrameError::Malformed`]), never the
//! server.

// audit:connection-facing — a hostile peer must kill only its own
// connection; mcma-audit bans panics and unchecked indexing here.
// audit:lock-ordered — shared mutexes follow the fixed acquisition
// order batch_rx -> registry -> reader_threads; mcma-audit reports any
// out-of-order nesting in this file.

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{Response, Server, ServerReport, Submitter};
use crate::obs::{Event, Obs};
use crate::util::lock_unpoisoned;

use super::frame::{
    decode_request, decode_stats_request, encode_response, encode_stats_response,
    route_to_wire, FrameError, FramePoll, FrameReader, KIND_STATS,
};

/// Socket read timeout: how often reader threads wake to check the stop
/// flag.  Partial frame progress is preserved across wakeups by
/// [`FrameReader`].
const READ_TICK: Duration = Duration::from_millis(25);

/// Pump-side egress poll granularity (stop-flag check cadence).
const PUMP_TICK: Duration = Duration::from_millis(25);

/// A stuck client gets this long to accept a response write before the
/// connection is declared dead (keeps one unread socket from stalling
/// the pump).
const WRITE_TIMEOUT: Duration = Duration::from_secs(1);

/// How long the pump keeps waiting for in-flight responses after stop,
/// as long as progress continues.
const QUIESCE_GRACE: Duration = Duration::from_millis(300);

/// Per-connection state shared by its reader thread and the pump.
struct Conn {
    writer: TcpStream,
    /// Slot-indexed client ids for requests in flight.
    pending: Vec<u64>,
    free: Vec<u32>,
    in_flight: u64,
    dead: bool,
    /// Reused response encode buffer (zero-alloc steady-state writes).
    write_buf: Vec<u8>,
    /// STATS scrapes awaiting an answer — bumped by the reader thread,
    /// drained by the response pump (which composes the snapshot JSON
    /// once per tick no matter how many connections asked).
    stats_pending: u32,
}

impl Conn {
    fn new(writer: TcpStream) -> Self {
        Conn {
            writer,
            pending: Vec::new(),
            free: Vec::new(),
            in_flight: 0,
            dead: false,
            write_buf: Vec::new(),
            stats_pending: 0,
        }
    }

    fn alloc_slot(&mut self, client_id: u64) -> u32 {
        self.in_flight += 1;
        match self.free.pop() {
            Some(s) => {
                // Free-listed slots were handed out of `pending`, so the
                // lookup cannot miss; stay total regardless.
                if let Some(p) = self.pending.get_mut(s as usize) {
                    *p = client_id;
                }
                s
            }
            None => {
                self.pending.push(client_id);
                (self.pending.len() - 1) as u32
            }
        }
    }

    /// Client id for the slot, or `None` for a slot this connection
    /// never allocated — the caller counts that as a failed delivery
    /// instead of letting a corrupt id echo panic the pump.
    fn release_slot(&mut self, slot: u32) -> Option<u64> {
        let client_id = *self.pending.get(slot as usize)?;
        self.free.push(slot);
        self.in_flight = self.in_flight.saturating_sub(1);
        Some(client_id)
    }
}

type Registry = Arc<Mutex<HashMap<u32, Conn>>>;

/// Final accounting after [`NetServer::shutdown`].
#[derive(Debug)]
pub struct NetReport {
    /// Connections accepted over the server's lifetime.
    pub accepted: u64,
    /// Connections killed for protocol violations (bad frame, wrong
    /// tag, wrong row width).
    pub malformed: u64,
    /// Responses whose connection was already dead at delivery time —
    /// counted, collected, and therefore never stalling the drain.
    pub delivery_failed: u64,
    /// The inner pipeline's report (latency, routes, batches, QoS).
    pub server: ServerReport,
}

/// Running TCP front-end; `shutdown` tears the whole stack down and
/// returns the end-to-end report.
pub struct NetServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    pump_stop: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
    reader_threads: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    pump_thread: Option<thread::JoinHandle<crate::Result<(ServerReport, u64)>>>,
    accepted: Arc<AtomicU64>,
    malformed: Arc<AtomicU64>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral test port) and
    /// start serving the pipeline over it.  `tag` is the tenant tag
    /// requests must carry (single-tenant: 0); `d_in` the row width
    /// requests must have — both checked before anything reaches the
    /// batcher, so a hostile frame can never panic the pipeline.
    pub fn spawn(server: Server, addr: &str, tag: u16, d_in: usize) -> crate::Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let stop = Arc::new(AtomicBool::new(false));
        let pump_stop = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let malformed = Arc::new(AtomicU64::new(0));
        let registry: Registry = Arc::new(Mutex::new(HashMap::new()));
        let reader_threads: Arc<Mutex<Vec<thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let submitter = server.submitter();
        // Pulled before the pump takes ownership of the Server; readers
        // and the pump record into the same plane the pipeline threads do.
        let obs = server.obs();

        // Response pump: sole owner of the Server.  Delivers responses
        // to their sockets as they arrive and keeps every one (delivered
        // or not) in `collected`, which makes the final shutdown drain
        // exact even when clients died mid-batch.  It also answers
        // in-band STATS scrapes: readers bump `Conn::stats_pending`, the
        // pump writes the snapshot frames from the same thread that owns
        // response ordering, so a scrape reply never interleaves into
        // the middle of a response frame.
        let pump_thread = {
            let registry = Arc::clone(&registry);
            let pump_stop = Arc::clone(&pump_stop);
            let submitter = submitter.clone();
            let obs = obs.clone();
            thread::Builder::new().name("mcma-net-pump".into()).spawn(
                move || -> crate::Result<(ServerReport, u64)> {
                    let mut collected: Vec<Response> = Vec::new();
                    let mut delivery_failed = 0u64;
                    loop {
                        answer_stats(&registry, &obs);
                        match server.recv_timeout(PUMP_TICK) {
                            Some(resp) => {
                                deliver(&registry, &resp, &mut delivery_failed, &obs);
                                collected.push(resp);
                            }
                            None => {
                                if !pump_stop.load(Ordering::Acquire) {
                                    continue;
                                }
                                // Stop requested and the readers are
                                // already joined (no new submits): keep
                                // draining while progress holds, then
                                // hand the exact set to shutdown.
                                let mut deadline = Instant::now() + QUIESCE_GRACE;
                                while submitter.submitted() > collected.len() as u64 {
                                    match server.recv_timeout(PUMP_TICK) {
                                        Some(resp) => {
                                            deliver(
                                                &registry,
                                                &resp,
                                                &mut delivery_failed,
                                                &obs,
                                            );
                                            collected.push(resp);
                                            deadline = Instant::now() + QUIESCE_GRACE;
                                        }
                                        None => {
                                            if Instant::now() >= deadline {
                                                break;
                                            }
                                        }
                                    }
                                }
                                answer_stats(&registry, &obs);
                                let report = server.shutdown(collected)?;
                                return Ok((report, delivery_failed));
                            }
                        }
                    }
                },
            )?
        };

        // Accept loop: nonblocking accept + stop-flag poll.  Each
        // connection gets a reader thread; accepted sockets are switched
        // back to blocking mode explicitly (they do not reliably inherit
        // the listener's flags) with a short read timeout for stop
        // checks.
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let registry = Arc::clone(&registry);
            let reader_threads = Arc::clone(&reader_threads);
            let accepted = Arc::clone(&accepted);
            let malformed = Arc::clone(&malformed);
            thread::Builder::new().name("mcma-net-accept".into()).spawn(move || {
                let mut next_conn_id: u32 = 1;
                while !stop.load(Ordering::Acquire) {
                    let stream = match listener.accept() {
                        Ok((s, _)) => s,
                        Err(_) => {
                            thread::sleep(Duration::from_millis(10));
                            continue;
                        }
                    };
                    if stream.set_nonblocking(false).is_err()
                        || stream.set_read_timeout(Some(READ_TICK)).is_err()
                        || stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err()
                    {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let writer = match stream.try_clone() {
                        Ok(w) => w,
                        Err(_) => continue,
                    };
                    let conn_id = next_conn_id;
                    next_conn_id = next_conn_id.wrapping_add(1);
                    // audit:allow(atomics) — monotone counter, read once in shutdown after joins
                    accepted.fetch_add(1, Ordering::Relaxed);
                    obs.metrics.accepted_conns.inc();
                    lock_unpoisoned(&registry).insert(conn_id, Conn::new(writer));
                    let spawned = thread::Builder::new()
                        .name(format!("mcma-net-conn-{conn_id}"))
                        .spawn({
                            let stop = Arc::clone(&stop);
                            let registry = Arc::clone(&registry);
                            let malformed = Arc::clone(&malformed);
                            let submitter = submitter.clone();
                            let obs = obs.clone();
                            move || {
                                read_connection(
                                    conn_id, stream, &registry, &submitter, &stop,
                                    &malformed, &obs, tag, d_in,
                                )
                            }
                        });
                    match spawned {
                        Ok(h) => lock_unpoisoned(&reader_threads).push(h),
                        Err(_) => {
                            lock_unpoisoned(&registry).remove(&conn_id);
                        }
                    }
                }
            })?
        };

        Ok(NetServer {
            local_addr,
            stop,
            pump_stop,
            accept_thread: Some(accept_thread),
            reader_threads,
            pump_thread: Some(pump_thread),
            accepted,
            malformed,
        })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, join readers, drain in-flight responses, shut the
    /// pipeline down, and report.
    pub fn shutdown(mut self) -> crate::Result<NetReport> {
        // Order matters: readers first (no new submits), then the pump
        // (drain + exact Server::shutdown accounting).
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let readers: Vec<_> = lock_unpoisoned(&self.reader_threads).drain(..).collect();
        for t in readers {
            let _ = t.join();
        }
        self.pump_stop.store(true, Ordering::Release);
        let (server, delivery_failed) = self
            .pump_thread
            .take()
            .ok_or_else(|| anyhow::anyhow!("net pump thread already joined"))?
            .join()
            .map_err(|_| anyhow::anyhow!("net pump thread panicked"))??;
        Ok(NetReport {
            accepted: self.accepted.load(Ordering::Relaxed), // audit:allow(atomics) — read after accept/reader joins
            malformed: self.malformed.load(Ordering::Relaxed), // audit:allow(atomics) — read after accept/reader joins
            delivery_failed,
            server,
        })
    }
}

/// Deliver one response to its connection; dead or vanished connections
/// are counted, never waited on.  Submit → delivered latency (and the
/// pump stage it implies) is recorded ONLY for writes that actually
/// reached the socket — a dead client's responses land in
/// `delivery_failures`, never in the served-latency histograms.
fn deliver(registry: &Registry, resp: &Response, delivery_failed: &mut u64, obs: &Obs) {
    let conn_id = (resp.id >> 32) as u32;
    let slot = resp.id as u32;
    let mut reg = lock_unpoisoned(registry);
    let Some(conn) = reg.get_mut(&conn_id) else {
        *delivery_failed += 1;
        obs.metrics.delivery_failures.inc();
        return;
    };
    match conn.release_slot(slot) {
        None => {
            *delivery_failed += 1;
            obs.metrics.delivery_failures.inc();
        }
        Some(_) if conn.dead => {
            *delivery_failed += 1;
            obs.metrics.delivery_failures.inc();
        }
        Some(client_id) => {
            let batch_n = resp.batch_n.min(u16::MAX as u32) as u16;
            encode_response(
                &mut conn.write_buf,
                route_to_wire(resp.route),
                batch_n,
                client_id,
                &resp.y,
            );
            if conn.writer.write_all(&conn.write_buf).is_err() {
                conn.dead = true;
                *delivery_failed += 1;
                obs.metrics.delivery_failures.inc();
                let _ = conn.writer.shutdown(Shutdown::Both);
            } else {
                let e2e_us = resp.submitted.elapsed().as_micros() as u64;
                let pump_us = (e2e_us as f64 - resp.latency_us).max(0.0) as u64;
                obs.metrics.stage_pump.record(pump_us);
                obs.metrics.e2e_delivered.record(e2e_us);
                obs.metrics.delivered.inc();
                if obs.journal.sampled(resp.id) {
                    obs.journal.push(Event::Delivered {
                        id: resp.id,
                        pump_us,
                        e2e_us,
                        at_us: obs.journal.now_us(),
                    });
                }
            }
        }
    }
    if conn.dead && conn.in_flight == 0 {
        reg.remove(&conn_id);
    }
}

/// Answer every pending STATS scrape.  The snapshot JSON is composed at
/// most once per call, then written to each asking connection through
/// its reused write buffer.  Runs on the pump thread, so scrape replies
/// serialise with response frames on each socket.
fn answer_stats(registry: &Registry, obs: &Obs) {
    let mut reg = lock_unpoisoned(registry);
    if !reg.values().any(|c| c.stats_pending > 0) {
        return;
    }
    let json = crate::util::json::write(&obs.snapshot_json());
    let bytes = json.as_bytes();
    for conn in reg.values_mut() {
        while conn.stats_pending > 0 {
            conn.stats_pending -= 1;
            if conn.dead {
                continue;
            }
            encode_stats_response(&mut conn.write_buf, bytes);
            if conn.writer.write_all(&conn.write_buf).is_err() {
                conn.dead = true;
                let _ = conn.writer.shutdown(Shutdown::Both);
            }
        }
    }
}

/// Reader-thread body: decode frames, validate, submit.  Any protocol
/// violation (bad frame, wrong tag, wrong row width) or transport error
/// kills this connection only — including a malformed STATS frame.
#[allow(clippy::too_many_arguments)]
fn read_connection(
    conn_id: u32,
    mut stream: TcpStream,
    registry: &Registry,
    submitter: &Submitter,
    stop: &AtomicBool,
    malformed: &AtomicU64,
    obs: &Obs,
    tag: u16,
    d_in: usize,
) {
    let mut fr = FrameReader::new();
    let mut protocol_violation = false;
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match fr.poll(&mut stream) {
            Ok(FramePoll::Pending) => continue,
            Ok(FramePoll::Closed) => break,
            Ok(FramePoll::Frame) => {
                let t_decode = Instant::now();
                // In-band STATS scrape: a bare header frame with the
                // stats kind.  Validated like any other frame (malformed
                // kills only this connection), then queued for the pump
                // to answer — the reader never writes to the socket.
                if fr.payload().get(1) == Some(&KIND_STATS) {
                    match decode_stats_request(fr.payload()) {
                        Ok(h) if h.tag == tag => {
                            obs.metrics.frames_in.inc();
                            obs.metrics.stats_requests.inc();
                            obs.metrics.tags.record(h.tag);
                            let mut reg = lock_unpoisoned(registry);
                            let Some(conn) = reg.get_mut(&conn_id) else { break };
                            conn.stats_pending = conn.stats_pending.saturating_add(1);
                            continue;
                        }
                        _ => {
                            protocol_violation = true;
                            break;
                        }
                    }
                }
                let mut row = Vec::new();
                let head = match decode_request(fr.payload(), &mut row) {
                    Ok(h) => h,
                    Err(_) => {
                        protocol_violation = true;
                        break;
                    }
                };
                if head.tag != tag || row.len() != d_in {
                    protocol_violation = true;
                    break;
                }
                obs.metrics.frames_in.inc();
                obs.metrics.tags.record(head.tag);
                obs.metrics
                    .stage_decode
                    .record(t_decode.elapsed().as_micros() as u64);
                let global_id = {
                    let mut reg = lock_unpoisoned(registry);
                    let Some(conn) = reg.get_mut(&conn_id) else { break };
                    let slot = conn.alloc_slot(head.id);
                    ((conn_id as u64) << 32) | slot as u64
                };
                if submitter.submit(global_id, row).is_err() {
                    // Pipeline ingress closed under us: roll the slot
                    // back (no response will ever arrive for it) and
                    // stop reading.
                    let mut reg = lock_unpoisoned(registry);
                    if let Some(conn) = reg.get_mut(&conn_id) {
                        let _ = conn.release_slot(global_id as u32);
                    }
                    break;
                }
            }
            Err(FrameError::Malformed(_)) => {
                protocol_violation = true;
                break;
            }
            Err(FrameError::Io(_)) => break,
        }
    }
    if protocol_violation {
        // audit:allow(atomics) — monotone counter, read once in shutdown after joins
        malformed.fetch_add(1, Ordering::Relaxed);
        obs.metrics.malformed_frames.inc();
    }
    obs.metrics.closed_conns.inc();
    let _ = stream.shutdown(Shutdown::Both);
    let mut reg = lock_unpoisoned(registry);
    if let Some(conn) = reg.get_mut(&conn_id) {
        conn.dead = true;
        if conn.in_flight == 0 {
            reg.remove(&conn_id);
        }
    }
}
