//! Network serving front-end + seeded load harness.
//!
//! Three pieces, layered over the in-process pipeline without changing
//! it:
//!
//! * [`frame`] — the little-endian length-prefixed wire format (version
//!   byte, hard size caps, connection-fatal-only malformed errors),
//!   including the in-band STATS scrape frames (`KIND_STATS`).
//! * [`listener`] — [`NetServer`]: accept loop, per-connection reader
//!   threads feeding the existing submit path, and the response pump
//!   that owns the [`crate::coordinator::Server`], keeps its shutdown
//!   accounting exact even when clients die mid-batch, and answers
//!   STATS scrapes with the live [`crate::obs`] snapshot.
//! * [`load`] — `mcma bench-load`: seeded open-loop (Poisson) /
//!   closed-loop request generation over the served workload's held-out
//!   rows, with client-observed latency percentiles, per-route counts,
//!   batch-size histogram and QoS violation scoring.
//! * [`http`] — [`MetricsServer`]: the hand-rolled HTTP/1.1 exposition
//!   endpoint (`serve --metrics-listen ADDR`) answering `GET /metrics`
//!   (OpenMetrics text) and `GET /healthz` (SLO-gated), under the same
//!   malformed-input-kills-only-its-connection contract as [`frame`].

pub mod frame;
pub mod http;
pub mod listener;
pub mod load;

pub use frame::{
    FrameError, FramePoll, FrameReader, FRAME_VERSION, KIND_STATS, MAX_STATS_BYTES,
    ROUTE_CPU,
};
pub use http::{http_get, MetricsServer};
pub use listener::{NetReport, NetServer};
pub use load::{scrape_stats, Arrival, LoadConfig, LoadReport};
