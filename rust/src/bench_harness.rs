//! Timing harness for `cargo bench` (criterion substitute).
//!
//! Warm-up, calibrated iteration counts, and mean/p50/p95/p99/std
//! reporting.  Figure benches also use `Table` to print paper-style rows.
//! [`Recorder`] additionally collects every timing and serialises them to
//! a JSON report (e.g. `BENCH_hotpath.json` at the repo root) so the perf
//! trajectory is machine-comparable across PRs.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::{json, stats};

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub std_ns: f64,
    /// Rows processed per iteration, when the case is a batched kernel —
    /// enables throughput (rows/sec) comparison across precisions.
    pub rows: Option<u64>,
}

impl Timing {
    /// Throughput in rows/second (batched kernel cases only).
    pub fn rows_per_sec(&self) -> Option<f64> {
        self.rows
            .filter(|_| self.mean_ns > 0.0)
            .map(|r| r as f64 * 1e9 / self.mean_ns)
    }

    pub fn print(&self) {
        let tail = match self.rows_per_sec() {
            Some(rps) => format!("  {:>12.0} rows/s", rps),
            None => String::new(),
        };
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  p99 {:>12}  ±{:>10}{tail}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            fmt_ns(self.std_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, auto-calibrating the iteration count to fill ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, f: F) -> Timing {
    bench_impl(name, budget, None, f)
}

/// [`bench`] for a batched kernel processing `rows` rows per iteration:
/// the timing additionally reports rows/sec throughput.
pub fn bench_with_rows<F: FnMut()>(name: &str, budget: Duration, rows: u64, f: F) -> Timing {
    bench_impl(name, budget, Some(rows), f)
}

fn bench_impl<F: FnMut()>(name: &str, budget: Duration, rows: Option<u64>, mut f: F) -> Timing {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = budget.as_nanos() as f64;
    let iters = ((target / once).clamp(3.0, 10_000.0)) as u64;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let timing = Timing {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p95_ns: stats::percentile(&samples, 95.0),
        p99_ns: stats::percentile(&samples, 99.0),
        std_ns: stats::std_dev(&samples),
        rows,
    };
    timing.print();
    timing
}

/// Build a [`Timing`] from externally-collected duration samples (ns) —
/// e.g. per-request serve latencies from `mcma bench-load` — so
/// measurements that don't come from a `bench` closure still flow
/// through the same [`Recorder`] JSON schema.  `rows` is rows per
/// sample, as in [`bench_with_rows`].
pub fn timing_from_samples(name: &str, samples_ns: &[f64], rows: Option<u64>) -> Timing {
    Timing {
        name: name.to_string(),
        iters: samples_ns.len() as u64,
        mean_ns: stats::mean(samples_ns),
        p50_ns: stats::percentile(samples_ns, 50.0),
        p95_ns: stats::percentile(samples_ns, 95.0),
        p99_ns: stats::percentile(samples_ns, 99.0),
        std_ns: stats::std_dev(samples_ns),
        rows,
    }
}

/// Collects [`Timing`]s across a bench binary and writes the
/// machine-readable JSON report consumed by cross-PR perf tracking.
#[derive(Default)]
pub struct Recorder {
    pub timings: Vec<Timing>,
    /// Scalar side-measurements (not timings) carried into the JSON
    /// report under `"extras"` — e.g. mean k-d-tree visits per query.
    pub extras: Vec<(String, f64)>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Record a scalar side-measurement for the JSON report.
    pub fn extra(&mut self, key: &str, v: f64) {
        self.extras.push((key.to_string(), v));
    }

    /// Run [`bench`] and keep the timing.
    pub fn bench<F: FnMut()>(&mut self, name: &str, budget: Duration, f: F) {
        self.timings.push(bench(name, budget, f));
    }

    /// Run [`bench_with_rows`] and keep the timing (adds rows/sec to the
    /// JSON report — the cross-precision throughput comparison).
    pub fn bench_rows<F: FnMut()>(&mut self, name: &str, budget: Duration, rows: u64, f: F) {
        self.timings.push(bench_with_rows(name, budget, rows, f));
    }

    /// Write all recorded timings as JSON:
    /// `{"suite": ..., "unix_time": ..., "results": [{name, iters, mean_ns,
    /// p50_ns, p95_ns, p99_ns, std_ns}, ...]}`.
    pub fn write_json(&self, suite: &str, path: &Path) -> crate::Result<()> {
        let unix_time = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        let results: Vec<json::Value> = self
            .timings
            .iter()
            .map(|t| {
                let mut fields = vec![
                    ("name", json::Value::Str(t.name.clone())),
                    ("iters", json::Value::Num(t.iters as f64)),
                    ("mean_ns", json::Value::Num(t.mean_ns)),
                    ("p50_ns", json::Value::Num(t.p50_ns)),
                    ("p95_ns", json::Value::Num(t.p95_ns)),
                    ("p99_ns", json::Value::Num(t.p99_ns)),
                    ("std_ns", json::Value::Num(t.std_ns)),
                ];
                if let (Some(rows), Some(rps)) = (t.rows, t.rows_per_sec()) {
                    fields.push(("rows", json::Value::Num(rows as f64)));
                    fields.push(("rows_per_sec", json::Value::Num(rps)));
                }
                json::obj(fields)
            })
            .collect();
        let mut doc_fields = vec![
            ("suite", json::Value::Str(suite.to_string())),
            ("unix_time", json::Value::Num(unix_time)),
            ("results", json::Value::Arr(results)),
        ];
        let extras = json::Value::Obj(
            self.extras.iter().map(|(k, v)| (k.clone(), json::Value::Num(*v))).collect(),
        );
        if !self.extras.is_empty() {
            doc_fields.push(("extras", extras));
        }
        let doc = json::obj(doc_fields);
        std::fs::write(path, json::write(&doc))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        println!("wrote {} ({} results)", path.display(), self.timings.len());
        Ok(())
    }
}

/// Where a bench binary should drop its JSON report: `MCMA_BENCH_JSON_DIR`
/// when set, else the repo root (benches run from `rust/`, so the root is
/// whichever of `.`/`..` holds `.git`), else the working directory.
pub fn bench_json_path(file: &str) -> PathBuf {
    if let Some(dir) = std::env::var_os("MCMA_BENCH_JSON_DIR") {
        return PathBuf::from(dir).join(file);
    }
    for base in [".", ".."] {
        if Path::new(base).join(".git").exists() {
            return Path::new(base).join(file);
        }
    }
    PathBuf::from(file)
}

/// Fixed-column text table for the figure benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let s: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", s.join("  "));
        };
        line(&self.header);
        println!(
            "  {}",
            widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Percent formatting helper used across figure tables.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let t = bench("noop-spin", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t.iters >= 3);
        assert!(t.mean_ns > 0.0);
        assert!(t.p95_ns >= t.p50_ns);
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["only-one".into()]);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn recorder_writes_parseable_json() {
        let mut rec = Recorder::new();
        rec.bench("tiny", Duration::from_millis(5), || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        rec.extra("lookup_visits_per_query", 11.5);
        let path = std::env::temp_dir()
            .join(format!("mcma_bench_recorder_test_{}.json", std::process::id()));
        rec.write_json("test-suite", &path).unwrap();
        let doc = crate::util::json::parse_file(&path).unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str().unwrap(), "test-suite");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "tiny");
        assert!(results[0].get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
        let extras = doc.get("extras").unwrap();
        assert_eq!(
            extras.get("lookup_visits_per_query").unwrap().as_f64().unwrap(),
            11.5
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_rows_reports_throughput() {
        let t = bench_with_rows("rows-case", Duration::from_millis(5), 256, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let rps = t.rows_per_sec().expect("rows/sec must be present");
        assert!((rps - 256.0 * 1e9 / t.mean_ns).abs() < 1e-6);
        // Plain bench carries no throughput.
        let plain = bench("plain", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(plain.rows_per_sec().is_none());

        let mut rec = Recorder::new();
        rec.timings.push(t);
        let path = std::env::temp_dir()
            .join(format!("mcma_bench_rows_test_{}.json", std::process::id()));
        rec.write_json("rows-suite", &path).unwrap();
        let doc = crate::util::json::parse_file(&path).unwrap();
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert!(results[0].get("rows_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(results[0].get("rows").unwrap().as_f64().unwrap(), 256.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn timing_from_samples_matches_stats() {
        let samples = [10.0, 20.0, 30.0, 40.0];
        let t = timing_from_samples("ext", &samples, Some(1));
        assert_eq!(t.iters, 4);
        assert!((t.mean_ns - 25.0).abs() < 1e-9);
        assert!(t.p50_ns >= 10.0 && t.p50_ns <= 40.0);
        assert!(t.rows_per_sec().is_some());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
