//! Timing harness for `cargo bench` (criterion substitute).
//!
//! Warm-up, calibrated iteration counts, and mean/p50/p95/std reporting.
//! Figure benches also use `Table` to print paper-style rows.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl Timing {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  ±{:>10}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.std_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, auto-calibrating the iteration count to fill ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Timing {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = budget.as_nanos() as f64;
    let iters = ((target / once).clamp(3.0, 10_000.0)) as u64;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let timing = Timing {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        p50_ns: stats::percentile(&samples, 50.0),
        p95_ns: stats::percentile(&samples, 95.0),
        std_ns: stats::std_dev(&samples),
    };
    timing.print();
    timing
}

/// Fixed-column text table for the figure benches.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let line = |cells: &[String]| {
            let s: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", s.join("  "));
        };
        line(&self.header);
        println!(
            "  {}",
            widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

/// Percent formatting helper used across figure tables.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let t = bench("noop-spin", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(t.iters >= 3);
        assert!(t.mean_ns > 0.0);
        assert!(t.p95_ns >= t.p50_ns);
    }

    #[test]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["only-one".into()]);
        }));
        assert!(result.is_err());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
