//! The model bank: every compiled executable + device weight set a
//! benchmark/method pair needs at run time, loaded once up front.

use std::collections::HashMap;
use std::path::Path;

use crate::config::Method;
use crate::formats::{BenchManifest, Manifest, WeightsFile};
use crate::nn::{Mlp, PackedMlp, PackedMlpQ8};

use super::{LoadedForward, Runtime, WeightSet};

/// Which network an executable implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    /// The approximator MLP (paper Fig. 6 "Approximator Topology").
    Approx,
    /// Binary safe/unsafe classifier (one-pass, iterative, MCCA stages).
    Clf2,
    /// Multiclass classifier (MCMA): n+1 output classes.
    ClfN,
}

impl Role {
    pub fn artifact_key(self) -> &'static str {
        match self {
            Role::Approx => "approx",
            Role::Clf2 => "clf2",
            Role::ClfN => "clfN",
        }
    }
}

/// Compiled executables (per role x batch) + device weights for one
/// benchmark.  Weight sets are keyed by (method, role, index) where index
/// enumerates approximators / cascade stages.
pub struct ModelBank {
    pub bench: String,
    exes: HashMap<(Role, usize), LoadedForward>,
    weights: HashMap<(Method, Role, usize), WeightSet>,
    /// Host-side copies for the native fallback engine and NPU cost model.
    pub host: WeightsFile,
    /// Host nets repacked once for the tiled GEMM engine (`nn::gemm`).
    /// Keyed by (method, is_approx, index) so hot-path lookups allocate
    /// nothing; Clf2/ClfN share the classifier slot like `host_mlp`.
    packed: HashMap<(Method, bool, usize), PackedMlp>,
    /// Int8 quantized twins of every host net (`nn::qgemm`), same keying —
    /// the `ExecMode::NativeQ8` engine.  Packed once alongside `packed`.
    packed_q8: HashMap<(Method, bool, usize), PackedMlpQ8>,
}

type PackedMaps = (
    HashMap<(Method, bool, usize), PackedMlp>,
    HashMap<(Method, bool, usize), PackedMlpQ8>,
);

/// Pack every host net reachable through a known [`Method`] for the tiled
/// native engines — an f32 packed net and its int8 quantized twin.  Runs
/// once at bank construction.
fn pack_host(host: &WeightsFile) -> PackedMaps {
    let mut packed = HashMap::new();
    let mut packed_q8 = HashMap::new();
    for m in Method::ALL {
        let Some(mw) = host.methods.get(m.key()) else { continue };
        for (i, net) in mw.approximators.iter().enumerate() {
            packed.insert((m, true, i), PackedMlp::from_mlp(net));
            packed_q8.insert((m, true, i), PackedMlpQ8::from_mlp(net));
        }
        for (i, net) in mw.classifiers.iter().enumerate() {
            packed.insert((m, false, i), PackedMlp::from_mlp(net));
            packed_q8.insert((m, false, i), PackedMlpQ8::from_mlp(net));
        }
    }
    (packed, packed_q8)
}

impl ModelBank {
    /// Load everything one benchmark needs for `methods` at `batches`.
    /// `rt = None` loads host weights only (native exec mode: no PJRT
    /// compilation, no device uploads).
    pub fn load(
        rt: Option<&Runtime>,
        man: &Manifest,
        bench: &BenchManifest,
        methods: &[Method],
        batches: &[usize],
    ) -> crate::Result<Self> {
        Self::load_with_weights(rt, man, bench, methods, batches, &man.weights_path(&bench.name))
    }

    /// Like `load` but from an explicit weights file (Fig. 7c loads the
    /// per-error-bound retrained variants `weights_bound_*.bin`).
    pub fn load_with_weights(
        rt: Option<&Runtime>,
        man: &Manifest,
        bench: &BenchManifest,
        methods: &[Method],
        batches: &[usize],
        weights_path: &Path,
    ) -> crate::Result<Self> {
        let host = WeightsFile::load(weights_path)?;
        let mut exes = HashMap::new();
        let mut weights = HashMap::new();

        let Some(rt) = rt else {
            let (packed, packed_q8) = pack_host(&host);
            return Ok(ModelBank {
                bench: bench.name.clone(),
                exes,
                weights,
                host,
                packed,
                packed_q8,
            });
        };

        let need_clf2 = methods.iter().any(|m| !m.is_mcma());
        let need_clfn = methods.iter().any(|m| m.is_mcma());

        for &b in batches {
            exes.insert(
                (Role::Approx, b),
                LoadedForward::load(
                    rt,
                    &man.hlo_path(&bench.name, "approx", b),
                    b,
                    &bench.approx_topology,
                )?,
            );
            if need_clf2 {
                exes.insert(
                    (Role::Clf2, b),
                    LoadedForward::load(
                        rt,
                        &man.hlo_path(&bench.name, "clf2", b),
                        b,
                        &bench.clf2_topology,
                    )?,
                );
            }
            if need_clfn {
                exes.insert(
                    (Role::ClfN, b),
                    LoadedForward::load(
                        rt,
                        &man.hlo_path(&bench.name, "clfN", b),
                        b,
                        &bench.clfn_topology,
                    )?,
                );
            }
        }

        for &m in methods {
            let mw = host.get(m.key())?;
            for (i, approx) in mw.approximators.iter().enumerate() {
                weights.insert((m, Role::Approx, i), WeightSet::upload(rt, approx)?);
            }
            let clf_role = if m.is_mcma() { Role::ClfN } else { Role::Clf2 };
            for (i, clf) in mw.classifiers.iter().enumerate() {
                weights.insert((m, clf_role, i), WeightSet::upload(rt, clf)?);
            }
        }

        let (packed, packed_q8) = pack_host(&host);
        Ok(ModelBank { bench: bench.name.clone(), exes, weights, host, packed, packed_q8 })
    }

    /// Build a native-only bank straight from host weights (no files, no
    /// PJRT) — lets unit tests craft classifiers/approximators with known
    /// behaviour and exercise the coordinator's routing semantics.
    pub fn from_host(bench: &str, host: WeightsFile) -> Self {
        let (packed, packed_q8) = pack_host(&host);
        ModelBank {
            bench: bench.to_string(),
            exes: HashMap::new(),
            weights: HashMap::new(),
            host,
            packed,
            packed_q8,
        }
    }

    /// The executable for `role` at exactly batch `b`.
    pub fn exe(&self, role: Role, b: usize) -> crate::Result<&LoadedForward> {
        self.exes
            .get(&(role, b))
            .ok_or_else(|| anyhow::anyhow!("no executable for {role:?} at batch {b}"))
    }

    /// Pick the compiled batch for `n` rows: the SMALLEST compiled size
    /// >= n (padding one call is far cheaper than chunking into many
    /// small dispatches — §Perf L3: routing groups are usually partial
    /// batches, and n sequential B=1 executes cost ~n x the per-dispatch
    /// overhead while one padded B=256 execute costs ~1x), falling back
    /// to the largest size (chunked) when n exceeds everything.
    pub fn best_batch(&self, role: Role, n: usize) -> usize {
        let mut sizes: Vec<usize> =
            self.exes.keys().filter(|(r, _)| *r == role).map(|(_, b)| *b).collect();
        sizes.sort_unstable();
        let n = n.max(1);
        for &s in &sizes {
            if s >= n {
                return s;
            }
        }
        *sizes.last().unwrap()
    }

    pub fn weight_set(&self, m: Method, role: Role, idx: usize) -> crate::Result<&WeightSet> {
        self.weights
            .get(&(m, role, idx))
            .ok_or_else(|| anyhow::anyhow!("no weights for {m:?}/{role:?}[{idx}]"))
    }

    /// Host-side net (native engine / cost model).
    pub fn host_mlp(&self, m: Method, role: Role, idx: usize) -> crate::Result<&Mlp> {
        let mw = self.host.get(m.key())?;
        match role {
            Role::Approx => mw
                .approximators
                .get(idx)
                .ok_or_else(|| anyhow::anyhow!("approximator {idx} out of range")),
            Role::Clf2 | Role::ClfN => mw
                .classifiers
                .get(idx)
                .ok_or_else(|| anyhow::anyhow!("classifier {idx} out of range")),
        }
    }

    /// Host net repacked for the tiled GEMM engine (native hot path).
    pub fn host_packed(&self, m: Method, role: Role, idx: usize) -> crate::Result<&PackedMlp> {
        let is_approx = role == Role::Approx;
        self.packed
            .get(&(m, is_approx, idx))
            .ok_or_else(|| anyhow::anyhow!("no packed host net for {m:?}/{role:?}[{idx}]"))
    }

    /// Int8 quantized twin of a host net (the `NativeQ8` hot path).
    pub fn host_packed_q8(
        &self,
        m: Method,
        role: Role,
        idx: usize,
    ) -> crate::Result<&PackedMlpQ8> {
        let is_approx = role == Role::Approx;
        self.packed_q8
            .get(&(m, is_approx, idx))
            .ok_or_else(|| anyhow::anyhow!("no quantized host net for {m:?}/{role:?}[{idx}]"))
    }

    /// Number of approximators available for `m`.
    pub fn n_approx(&self, m: Method) -> usize {
        self.host
            .methods
            .get(m.key())
            .map(|mw| mw.approximators.len())
            .unwrap_or(0)
    }

    /// Does the artifact tree have this benchmark+method?
    pub fn has_method(&self, m: Method) -> bool {
        self.host.methods.contains_key(m.key())
    }
}

/// Check that an artifacts directory looks complete for `bench`.
pub fn artifacts_present(root: &Path, bench: &str) -> bool {
    root.join(bench).join("weights.bin").exists()
        && root.join(bench).join("test.bin").exists()
        && root.join("manifest.json").exists()
}
