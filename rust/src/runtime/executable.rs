//! A compiled batched-MLP forward plus device-resident weight sets.

use std::path::Path;

use crate::nn::Mlp;

use super::Runtime;

/// Device-resident weights for one net (one buffer per W/b parameter, in
/// the exported parameter order W1, b1, W2, b2, ...).
pub struct WeightSet {
    pub buffers: Vec<xla::PjRtBuffer>,
    /// Total parameter words (for the NPU weight-switch model).
    pub n_words: usize,
}

impl WeightSet {
    /// Upload an `nn::Mlp`'s parameters to the device once.
    pub fn upload(rt: &Runtime, mlp: &Mlp) -> crate::Result<Self> {
        let mut buffers = Vec::with_capacity(mlp.layers.len() * 2);
        let mut n_words = 0usize;
        for layer in &mlp.layers {
            let w = rt
                .client()
                .buffer_from_host_buffer::<f32>(
                    &layer.w.data,
                    &[layer.w.rows, layer.w.cols],
                    None,
                )
                .map_err(|e| anyhow::anyhow!("uploading weights: {e:?}"))?;
            buffers.push(w);
            let b = rt
                .client()
                .buffer_from_host_buffer::<f32>(&layer.b, &[layer.b.len()], None)
                .map_err(|e| anyhow::anyhow!("uploading bias: {e:?}"))?;
            buffers.push(b);
            n_words += layer.w.data.len() + layer.b.len();
        }
        Ok(WeightSet { buffers, n_words })
    }
}

/// One compiled `f(x, W1, b1, ...) -> (y,)` executable at a fixed batch.
pub struct LoadedForward {
    exe: xla::PjRtLoadedExecutable,
    rt: Runtime,
    pub batch: usize,
    pub n_in: usize,
    pub n_out: usize,
    /// Weight/bias parameter count (2 per layer).
    pub n_weight_params: usize,
}

impl LoadedForward {
    /// Load + compile `path`, validating against the expected topology.
    pub fn load(
        rt: &Runtime,
        path: &Path,
        batch: usize,
        topology: &[usize],
    ) -> crate::Result<Self> {
        let exe = rt.load_hlo(path)?;
        Ok(LoadedForward {
            exe,
            rt: rt.clone(),
            batch,
            n_in: topology[0],
            n_out: *topology.last().unwrap(),
            n_weight_params: (topology.len() - 1) * 2,
        })
    }

    /// Execute on exactly `self.batch` rows already laid out in `x`.
    ///
    /// `x` longer than one batch is rejected; shorter is padded with zeros.
    /// Returns `(rows, n_out)` with only the first `n` rows meaningful.
    pub fn run(&self, x: &[f32], n: usize, weights: &WeightSet) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(n <= self.batch, "batch overflow: {n} > {}", self.batch);
        anyhow::ensure!(x.len() == n * self.n_in, "input buffer size mismatch");
        anyhow::ensure!(
            weights.buffers.len() == self.n_weight_params,
            "weight parameter count mismatch: {} vs {}",
            weights.buffers.len(),
            self.n_weight_params
        );

        // Upload activations (padded to the compiled batch).
        let xbuf = if n == self.batch {
            self.rt
                .client()
                .buffer_from_host_buffer::<f32>(x, &[self.batch, self.n_in], None)
        } else {
            let mut padded = vec![0.0f32; self.batch * self.n_in];
            padded[..x.len()].copy_from_slice(x);
            self.rt
                .client()
                .buffer_from_host_buffer::<f32>(&padded, &[self.batch, self.n_in], None)
        }
        .map_err(|e| anyhow::anyhow!("uploading activations: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.buffers.len());
        args.push(&xbuf);
        args.extend(weights.buffers.iter());

        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        // Exported with return_tuple=True -> unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let mut values = out
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        values.truncate(n * self.n_out);
        Ok(values)
    }
}
