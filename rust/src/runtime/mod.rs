//! PJRT runtime: load the AOT-lowered HLO text, compile once, execute on
//! the hot path.
//!
//! The exported modules are `f(x[B,in], W1, b1, ..., Wk, bk) -> (y[B,out],)`
//! — weights are RUNTIME PARAMETERS, so one compiled executable serves all
//! n approximators of a benchmark; switching approximators swaps device
//! buffers, never recompiles (the XLA analogue of the paper's §III.D
//! weight-buffer shipping).
//!
//! Perf notes (§Perf L3):
//! * weights are uploaded once per net as device-resident `PjRtBuffer`s
//!   (`WeightSet`), then every `execute_b` call passes borrowed buffers —
//!   only the activations cross the host/device boundary per call;
//! * partial batches are padded to the compiled batch size and sliced on
//!   the way back; a B=1 variant avoids padding waste in latency mode.

pub mod executable;
pub mod model_bank;

pub use executable::{LoadedForward, WeightSet};
pub use model_bank::{ModelBank, Role};

use std::sync::Arc;

/// Shared PJRT CPU client (cheap to clone: the underlying client is
/// reference-counted in the C layer; we wrap in Arc for rust-side clarity).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    /// Create the PJRT CPU client.
    pub fn cpu() -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO text file and compile it.
    pub fn load_hlo(&self, path: &std::path::Path) -> crate::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-UTF8 path {}", path.display()))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }
}
